//! Sharded multi-worker serving: a deterministic session router over a
//! pool of device workers — the paper's pool-of-general-purpose-cores
//! thesis (§3) lifted to the serving layer. [`ShardPool`] spawns
//! `ShardConfig::workers` shards, each owning its own [`Batcher`],
//! scratch arenas and acoustic-backend handle over the *shared* model
//! ([`Engine::clone_worker`] — weights behind an `Arc`), and a router
//! thread assigns sessions to shards.
//!
//! ## Sessions are movable state
//!
//! Per-session state is no longer shard-resident-by-construction: every
//! session serializes to a [`SessionSnapshot`] (acoustic lane state +
//! decoder state + buffered audio + counters, versioned and
//! checksummed), and three mechanisms ship those bytes:
//!
//! * **Live migration** — rebalancing evicts sessions from the hottest
//!   shard *mid-utterance* (evict → snapshot → adopt → restore), not
//!   just queued ones; restored sessions continue bit-identically
//!   (`tests/snapshot_parity.rs`). Only sessions with a feed in flight
//!   (staged in the batcher) are briefly pinned.
//! * **Recovery checkpoints** — after each batch flush a worker ships a
//!   fresh snapshot of every session that advanced
//!   `ShardConfig::checkpoint_interval` steps (before answering the
//!   flushed feeds, so an acknowledged feed is always covered by its
//!   checkpoint). The router retains the latest per session.
//! * **Dead-shard recovery** — when a worker's job channel disconnects
//!   (thread death, or the explicit [`ShardPool::kill_worker`] crash
//!   hook), the router re-adopts its sessions onto surviving shards
//!   from their checkpoints; never-checkpointed sessions reopen fresh
//!   (correct under acknowledged-snapshot semantics: no reply ever
//!   covered their audio). The client request that discovered the death
//!   is retried once on the session's new shard; feeds that were staged
//!   un-acknowledged on a [`ShardPool::kill_worker`] victim ride back
//!   on the death ack and are *replayed* on their sessions' recovery
//!   shards (staged audio always postdates the covering checkpoint, so
//!   the replay is exact and the client's pending request answers
//!   normally). Worker replies are generation-tagged: once the router
//!   declares a shard dead, any answer the dying worker still produces
//!   is dropped rather than racing the recovery path's own answer.
//!
//! A disconnected client re-attaches with the protocol's `resume` op:
//! the reply reports how many steps/samples the server has consumed so
//! the client can replay only unacknowledged audio.
//!
//! ## Determinism
//!
//! Transcripts are independent of the shard count *and* of migrations:
//! per-session decode state never crosses lanes, `Engine::step_batch`
//! is bit-identical to scalar decoding for every lane
//! (`tests/batch_parity.rs`), every worker serves the same weights, and
//! snapshot/restore is bit-exact — so any placement history yields
//! exactly the 1-worker transcripts (`tests/shard_parity.rs`,
//! `tests/snapshot_parity.rs`). *Initial* session→shard assignment is
//! deterministic (fewest open sessions, lowest index on ties); final
//! placement under load depends on wall-clock flush timing but never
//! affects transcripts.
//!
//! ## Flow control and overload
//!
//! Client-facing jobs are forwarded with a non-blocking `try_send`: a
//! shard whose queue is saturated bounces *its own* requests with
//! `backpressure` while the router keeps routing for every other shard
//! (head-of-line isolation). Router-internal migration work is fully
//! asynchronous: evict and adopt are fire-and-forget jobs whose
//! completions come back on a dedicated [`MigrEvent`] back-channel, so
//! the router never blocks on a worker round-trip (the only blocking
//! the router ever does is a queue-space wait when *dispatching* an
//! internal job, which is bounded by one queue's in-flight work, not by
//! a worker's answer). `stats` never waits on any worker at all: each
//! worker publishes its [`ShardSnapshot`] into a shared cache after
//! every state-changing job (before replying to it), and the router
//! aggregates the caches.
//!
//! ## Elastic pool
//!
//! The pool is elastic at runtime when `ShardConfig::max_workers`
//! allows it ([`ShardPool::add_worker`] / [`ShardPool::drain_worker`]):
//!
//! * **Scale up** — the router holds one *template* [`WorkerSeed`]
//!   cloned from the engine at startup and mints a fresh seed from it
//!   ([`WorkerSeed::clone_seed`]) for every added worker, so scale-up
//!   never touches a serving thread. New shards append at the next
//!   index; retired indices are never reused.
//! * **Drain (scale down / rolling swap)** — a draining shard stops
//!   accepting *new* sessions but keeps serving its current ones while
//!   the router pipeline-migrates them off in small evict batches over
//!   the PR 5 snapshot path, concurrently with live traffic. Once the
//!   last session has moved the worker gets a clean `Shutdown` and the
//!   shard is marked retired. A drain that cannot finish by
//!   `ShardConfig::drain_deadline_ms` aborts and reverts the shard to
//!   active — sessions already migrated stay where they landed.
//!
//! Sessions with a migration leg in flight are *parked*: their client
//! jobs queue in arrival order inside the router and replay on the
//! destination shard the moment the adopt completes, so migration is
//! invisible to clients (same replies, bit-identical transcripts).
//!
//! An [`OverloadPolicy`] (default: everything off) layers SLO-aware
//! control on top:
//!
//! * **Admission control** — once a shard would exceed
//!   `admit_sessions_per_shard` open sessions, new `open`s are refused
//!   with `backpressure` carrying a `retry_after_ms` hint (every
//!   policy-driven bounce carries the hint).
//! * **Retry/backoff routing** — a full (slow, suspect) shard queue is
//!   retried `route_retries` times with doubling backoff before the
//!   client sees the bounce. The waiting happens on a per-shard
//!   *deferred-retry queue* drained by the 25 ms supervisor tick — the
//!   router thread never sleeps, and per-session FIFO order is
//!   preserved (a job for a session with deferred work joins the back
//!   of the queue instead of overtaking it). Worker *death* is never
//!   retried against — it is detected and recovered (below).
//! * **Load shedding** — when a feed still bounces off a saturated
//!   shard, the shard's oldest *never started* session (opened, zero
//!   audio fed) is shed to make room; started sessions are never shed.
//! * **Graceful degradation** — each worker measures its decode backlog
//!   (ready steps over its open sessions) at every flush and steps
//!   through the policy's degrade ladder (narrower beam via the
//!   decoder config, tighter lane budget via the [`Batcher`] cap). The
//!   backlog is a pure function of the admitted feed trace (FIFO per
//!   shard), and the ladder is threshold-only (no hysteresis), so the
//!   rung at every flush — and therefore every transcript — is
//!   deterministic for a given trace, and full quality returns the
//!   moment pressure drains (level 0 *is* the configured config).
//!
//! ## Liveness supervision
//!
//! Worker threads run under `catch_unwind`. A panicking worker closes
//! its job queue, rescues its staged (accepted, never acknowledged)
//! feeds and still-queued client jobs, and posts a death report into a
//! shared [`WorkerLiveness`] slot. The router polls the slots between
//! messages (and on a short idle timeout), so a *spontaneous* panic is
//! discovered by the supervisor — not by the next send — and triggers
//! the same checkpoint re-adoption + staged-feed replay the
//! [`ShardPool::kill_worker`] drill exercises. The drill itself is now
//! *implemented as* an injected panic ([`Job::Die`] panics in the
//! worker loop), so the test path and the real path are one code path.
//! Workers also publish a heartbeat counter through their
//! [`ShardSnapshot`] caches (`stats` surfaces it) for observability.
//!
//! The TCP front-end ([`super::Server`]) is a thin protocol layer over
//! this module; tests and examples drive [`ShardPool`] directly — no
//! sockets, no JSON text round-trips, which is what lets the parity
//! suites demand *bit*-identical scores.
#![deny(missing_docs)]

use anyhow::{Context, Result};
use std::collections::{BTreeSet, HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::{OverloadPolicy, ShardConfig};
use crate::util::json::Json;

use super::engine::{Batcher, Engine, Session, WorkerSeed};
use super::metrics::{ServeMetrics, ShardLifecycle, ShardMetrics, ShardSnapshot};
use super::server::{backpressure_json, config_json, err_json, obj, ErrCode};
use super::snapshot::SessionSnapshot;

/// How long the router waits for a message before running a supervision
/// pass anyway — the upper bound on how long a spontaneously-panicked
/// worker stays undetected on an otherwise idle pool.
const SUPERVISE_INTERVAL: Duration = Duration::from_millis(25);

/// Upper bound on the kill drill's wait for the victim's death report;
/// only a wedged worker (stuck in the device backend) can hit it, and
/// the drill then proceeds exactly as if the staged feeds were lost.
const KILL_REPORT_WAIT: Duration = Duration::from_secs(10);

/// How many sessions one drain-driven evict batch asks a worker to
/// snapshot at once. Small enough that the draining worker keeps
/// serving between batches (migration is pipelined with traffic), large
/// enough that a drain converges in a few supervisor ticks.
const DRAIN_EVICT_BATCH: usize = 8;

/// A client-facing request the router dispatches. Both front-ends speak
/// this: TCP connection threads (`super::Server`) and the in-process
/// [`ShardPool`] wrappers.
pub(crate) enum RouterMsg {
    /// Open a session on the least-loaded shard.
    Open { reply: mpsc::Sender<Json> },
    /// Feed audio to an open session (routed to its shard).
    Feed { session: u64, samples: Vec<f32>, enqueued: Instant, reply: mpsc::Sender<Json> },
    /// Finish a session and retire its assignment.
    Finish { session: u64, reply: mpsc::Sender<Json> },
    /// Finish a session and return its exact N-best list (with
    /// second-pass scores when the engine rescores). Unlike `Finish`
    /// the assignment is not retired at dispatch — the worker un-books
    /// via the retire back-channel only once it commits to consuming
    /// the session, so a refusal (engine without N-best) leaves the
    /// session open.
    Nbest { session: u64, reply: mpsc::Sender<Json> },
    /// Re-attach to a session: report consumed steps/samples + partial.
    Resume { session: u64, reply: mpsc::Sender<Json> },
    /// Aggregate per-shard metrics (served from the stats caches).
    Stats { reply: mpsc::Sender<Json> },
    /// Device/config introspection (served by the first live shard).
    Config { reply: mpsc::Sender<Json> },
    /// Crash one worker uncleanly and recover its sessions from their
    /// checkpoints (test/ops hook behind [`ShardPool::kill_worker`]).
    Kill { shard: usize, reply: mpsc::Sender<Json> },
    /// Add one worker to the pool, seeded from the router's template
    /// seed (elastic scale-up; bounded by `ShardConfig::max_workers`).
    PoolAdd { reply: mpsc::Sender<Json> },
    /// Drain one worker: stop assigning new sessions to it, migrate its
    /// live sessions off concurrently with serving, then retire it.
    /// The reply arrives when the drain finishes (or aborts on its
    /// deadline) — the router itself never waits.
    PoolDrain { shard: usize, reply: mpsc::Sender<Json> },
    /// Report every shard's lifecycle + load (the `pool` op's `status`).
    PoolStatus { reply: mpsc::Sender<Json> },
    /// Stop the router and every worker.
    Shutdown,
}

/// A client reply channel, optionally tagged with the generation of the
/// worker its job was routed to. The router advances a shard's
/// generation the moment it declares the shard dead
/// ([`Router::mark_dead`]) — from then on a send through a tag taken
/// against the older generation is dropped, so a reply the dying worker
/// still manages to produce can never race the answer the router's
/// recovery path issues for the same request.
struct Reply {
    tx: mpsc::Sender<Json>,
    guard: Option<(u64, Arc<AtomicU64>)>,
}

impl Reply {
    fn new(tx: mpsc::Sender<Json>) -> Reply {
        Reply { tx, guard: None }
    }

    /// Tag with the target worker's current generation; a later bump
    /// (the shard was declared dead) invalidates the tag.
    fn tag(&mut self, generation: &Arc<AtomicU64>) {
        self.guard = Some((generation.load(Ordering::SeqCst), Arc::clone(generation)));
    }

    /// Drop the tag — the router itself is about to answer (bounce,
    /// out-of-retries, lost-session replay), which is always current.
    fn untag(&mut self) {
        self.guard = None;
    }

    /// Deliver unless the tagged worker generation has moved on.
    fn send(&self, payload: Json) {
        if let Some((tagged, cur)) = &self.guard {
            if cur.load(Ordering::SeqCst) != *tagged {
                return;
            }
        }
        let _ = self.tx.send(payload);
    }
}

/// A unit of work queued to one shard's device worker.
enum Job {
    /// Open a session under a router-assigned globally unique id.
    Open { id: u64, reply: Reply },
    /// Stage audio + run the lane-batched device loop.
    Feed { session: u64, samples: Vec<f32>, enqueued: Instant, reply: Reply },
    /// Flush and extract the transcript.
    Finish { session: u64, reply: Reply },
    /// Flush and extract the transcript plus the exact N-best list
    /// (rescored when the engine carries a second-pass LM).
    Nbest { session: u64, reply: Reply },
    /// Report a session's consumed steps/frames/buffer + partial.
    Resume { session: u64, reply: Reply },
    /// Introspect the engine this worker serves.
    Config { reply: Reply },
    /// Snapshot the named sessions off this shard for adoption
    /// elsewhere. Asynchronous: the worker answers with
    /// [`MigrEvent::Evicted`] on the migration back-channel, carrying
    /// `(id, capture seq, encoded snapshot)` triples for the sessions
    /// it could capture and the ids it kept (pinned in the batcher,
    /// already gone, or un-snapshottable) — never a blocking
    /// round-trip on the router.
    Evict { ids: Vec<u64>, token: u64 },
    /// Restore a migrated/recovered session under its id. `None`
    /// re-opens fresh (a session that never had a checkpoint).
    /// Asynchronous: the worker answers with [`MigrEvent::Adopted`];
    /// a refusal hands the snapshot back so the router can re-adopt it
    /// elsewhere instead of destroying the session. `returning` marks a
    /// bounce-back to the origin shard after a failed migration —
    /// re-booked but not counted as adopted.
    Adopt { id: u64, snap: Option<Vec<u8>>, returning: bool, token: u64 },
    /// Router-initiated overload shedding: destroy a *never started*
    /// session (opened, zero audio fed) so a saturated shard frees a
    /// slot. No reply — the router already answered the client whose
    /// bounced feed triggered the shed, and the victim's owner learns on
    /// its next request (`session_shed`, with a reopen hint).
    Shed { session: u64 },
    /// Simulated crash: panic in the worker loop *without* flushing
    /// staged work or shipping final checkpoints. The panic unwinds into
    /// the same `catch_unwind` wrapper that catches real worker panics
    /// ([`run_worker`]), so the kill drill and spontaneous death share
    /// one rescue/report/recover code path.
    Die,
    /// Flush staged work and exit the worker loop.
    Shutdown,
}

impl Job {
    /// The client reply channel this job carries, if any — used to
    /// bounce the request when its shard's queue is saturated and to
    /// (re-)tag the reply with the target worker's generation.
    fn reply_mut(&mut self) -> Option<&mut Reply> {
        match self {
            Job::Open { reply, .. }
            | Job::Feed { reply, .. }
            | Job::Finish { reply, .. }
            | Job::Nbest { reply, .. }
            | Job::Resume { reply, .. }
            | Job::Config { reply } => Some(reply),
            Job::Evict { .. } | Job::Adopt { .. } | Job::Shed { .. } | Job::Die | Job::Shutdown => {
                None
            }
        }
    }

    /// The open session this job addresses, if any — how a retried job
    /// finds its session's new shard after dead-shard recovery.
    fn session_id(&self) -> Option<u64> {
        match self {
            Job::Feed { session, .. }
            | Job::Finish { session, .. }
            | Job::Nbest { session, .. }
            | Job::Resume { session, .. } => Some(*session),
            _ => None,
        }
    }
}

/// A migration-leg completion, posted by a worker on the unbounded
/// migration back-channel. The `token` names the [`Job::Evict`] /
/// [`Job::Adopt`] leg the router issued, so the router (which drains
/// this channel between messages and on every supervisor tick) can
/// resolve the leg without ever having waited on it.
enum MigrEvent {
    /// An evict batch ran on `shard`: `moved` sessions were captured
    /// (id, capture seq, encoded snapshot) and left the worker; `kept`
    /// ids stayed (pinned in the batcher, not resident, or not
    /// snapshottable) and remain served by the origin.
    Evicted { shard: usize, token: u64, moved: Vec<(u64, u64, Vec<u8>)>, kept: Vec<u64> },
    /// An adopt ran on `shard` for session `id`. `Ok(())` means the
    /// session is live there; `Err(Some(bytes))` hands the snapshot
    /// back for adoption elsewhere; `Err(None)` means the session could
    /// not be restored and no state survived.
    Adopted { shard: usize, token: u64, id: u64, outcome: Result<(), Option<Vec<u8>>> },
}

/// A feed waiting for its batch to flush. It keeps the audio it staged
/// so a worker dying before the flush can hand the un-acknowledged feed
/// back to the router as a replayable job ([`Job::Die`]).
struct StagedFeed {
    session: u64,
    samples: Vec<f32>,
    reply: Reply,
    enqueued: Instant,
}

/// One shard's device loop state: owns its engine, sessions, batcher
/// and metrics; drains jobs FIFO; never blocks sending (replies and the
/// retire/checkpoint back-channels are unbounded), so the router can
/// always make progress. The back-channels are deliberately *not* the
/// router's main queue: workers holding a main-queue sender would keep
/// the router alive after every client handle dropped (thread leak).
struct Worker {
    shard: usize,
    engine: Engine,
    depth: Arc<AtomicUsize>,
    /// Un-book back-channel (failed opens, poisoned batches).
    retire: mpsc::Sender<u64>,
    /// Recovery-checkpoint back-channel: (session, capture sequence
    /// number, encoded snapshot). The sequence number — strictly
    /// increasing per session across its whole lifetime, migrations
    /// included — lets the router ignore an older in-flight checkpoint
    /// that arrives after a fresher migration snapshot was already
    /// stored. Empty bytes are a *tombstone*: acknowledged state exists
    /// that could not be captured, so recovery must drop the session
    /// rather than reset it.
    ckpt: mpsc::Sender<(u64, u64, Vec<u8>)>,
    /// Migration back-channel: evict/adopt completions ([`MigrEvent`])
    /// flow back to the router here instead of over per-job reply
    /// channels, which is what makes migration legs asynchronous.
    migr: mpsc::Sender<MigrEvent>,
    /// The shared stats cache this worker publishes into.
    cache: Arc<Mutex<ShardSnapshot>>,
    sessions: HashMap<u64, Session>,
    metrics: ServeMetrics,
    batcher: Batcher,
    staged: Vec<StagedFeed>,
    /// Step count at each session's last shipped checkpoint.
    last_ckpt: HashMap<u64, usize>,
    ckpt_interval: usize,
    /// Monotone publish counter, surfaced through the stats cache as
    /// this worker's heartbeat: a live worker under traffic keeps
    /// advancing it, a dead or wedged one does not.
    heartbeats: u64,
    /// The degrade rung the last [`Worker::apply_degrade`] selected
    /// (0 = full quality), published through the stats cache.
    degrade_level: usize,
}

impl Worker {
    fn new(
        shard: usize,
        engine: Engine,
        depth: Arc<AtomicUsize>,
        retire: mpsc::Sender<u64>,
        ckpt: mpsc::Sender<(u64, u64, Vec<u8>)>,
        migr: mpsc::Sender<MigrEvent>,
        cache: Arc<Mutex<ShardSnapshot>>,
    ) -> Worker {
        let batcher = engine.batcher();
        let ckpt_interval = engine.shard_cfg.checkpoint_interval;
        Worker {
            shard,
            engine,
            depth,
            retire,
            ckpt,
            migr,
            cache,
            sessions: HashMap::new(),
            metrics: ServeMetrics::default(),
            batcher,
            staged: Vec::new(),
            last_ckpt: HashMap::new(),
            ckpt_interval,
            heartbeats: 0,
            degrade_level: 0,
        }
    }

    /// Publish this shard's live status into the shared stats cache.
    /// Called after every state-changing job, *before* its reply, so a
    /// client that has seen a reply also sees its effect in `stats`.
    /// The cached snapshot is overwritten in place (`clone_from`
    /// reuses the latency windows' capacity), so the steady-state
    /// publish allocates nothing.
    fn publish(&mut self) {
        self.heartbeats += 1;
        let mut cached = self.cache.lock().unwrap();
        cached.shard = self.shard;
        cached.open_sessions = self.sessions.len();
        cached.queue_depth = self.depth.load(Ordering::Relaxed);
        cached.heartbeats = self.heartbeats;
        cached.degrade_level = self.degrade_level;
        cached.serve.clone_from(&self.metrics);
    }

    /// Pick this shard's degrade rung from its current decode backlog
    /// (ready steps summed over every open session) and apply it to the
    /// engine's decoder and the batcher's lane budget. The backlog is a
    /// pure function of the feed trace this worker has accepted (jobs
    /// drain FIFO), and [`OverloadPolicy::level_for_backlog`] is a
    /// threshold ladder with no hysteresis — so for a given admitted
    /// trace the rung at every flush, and therefore every transcript, is
    /// deterministic, and rung 0 (the configured decoder, untouched)
    /// returns the moment pressure drains. With no ladder configured
    /// this is a no-op that always selects rung 0.
    fn apply_degrade(&mut self) -> usize {
        let backlog: usize =
            self.sessions.values().map(|s| self.engine.ready_steps(s)).sum();
        let level = self.engine.overload.level_for_backlog(backlog);
        self.engine.set_degrade_level(level);
        self.batcher.set_cap(self.engine.overload.batch_cap_at(level));
        self.degrade_level = level;
        level
    }

    /// Ship a recovery checkpoint if the session advanced at least
    /// `checkpoint_interval` steps since its last one (a session's first
    /// flush always checkpoints, so every flushed session is covered;
    /// interval 1 re-checkpoints at every flush so buffered-audio-only
    /// changes are captured too). Backends without snapshot support
    /// never checkpoint — their sessions are pinned and recovery drops
    /// them. A *transient* capture failure on a snapshot-capable
    /// backend ships a tombstone instead: the router then knows acked
    /// state exists that is no longer covered, and a crash drops the
    /// session rather than resetting it to an older (or fresh) state.
    fn maybe_checkpoint(&mut self, id: u64, s: &mut Session) {
        if self.ckpt_interval == 0 || !self.engine.backend().supports_lane_snapshots() {
            return;
        }
        let due = match self.last_ckpt.get(&id) {
            None => true,
            Some(&at) => {
                self.ckpt_interval == 1
                    || s.metrics.steps.saturating_sub(at) >= self.ckpt_interval
            }
        };
        if !due {
            return;
        }
        match self.engine.snapshot(s) {
            Ok(snap) => {
                let seq = s.metrics.snapshots_taken as u64;
                let _ = self.ckpt.send((id, seq, snap.encode()));
                self.metrics.checkpoints_published += 1;
                self.last_ckpt.insert(id, s.metrics.steps);
            }
            Err(_) => {
                let seq = s.metrics.snapshots_taken as u64;
                let _ = self.ckpt.send((id, seq, Vec::new()));
            }
        }
    }

    /// Run the pending batch: pull its sessions out of the map, fuse
    /// their ready steps through `Engine::step_batch`, record
    /// occupancy/latency, ship due checkpoints, publish the stats
    /// cache, then answer every staged feed with its session's step
    /// count + partial — strictly in that order, so an acknowledged feed
    /// is always covered by an already-enqueued checkpoint.
    ///
    /// A batch-level engine error **poisons** the fused step
    /// (`AmBackend::score_step_batch` contract: lane states may have
    /// advanced while no audio drained), so the batch's sessions are
    /// discarded — reinserting them would let a later feed/finish
    /// silently replay consumed audio against advanced state and return
    /// a corrupt transcript as success. Every staged feed gets the
    /// `internal` error, later ops on those ids get `unknown_session`,
    /// and the router is told through the `retire` back-channel to
    /// un-book them (which also drops their checkpoints).
    ///
    /// Known coarseness, acceptable at this layer: if one session was
    /// fed twice before the flush (two connections), both replies report
    /// the same since-staging step delta; and a batch-level engine error
    /// is reported to every staged feed in the batch, not just the
    /// failing lane's.
    fn flush(&mut self) {
        // Degrade decision first: the rung for this drain is a function
        // of the backlog *before* it drains.
        let level = self.apply_degrade();
        let ids = self.batcher.take();
        // Pull the batch's sessions out of the map so every lane can be
        // borrowed mutably at once; they go back right after the step.
        let mut lanes: Vec<(u64, Session, usize)> = Vec::with_capacity(ids.len());
        for id in ids {
            if let Some(s) = self.sessions.remove(&id) {
                let steps_before = s.metrics.steps;
                lanes.push((id, s, steps_before));
            }
        }
        let occupancy = lanes
            .iter()
            .filter(|(_, s, _)| self.engine.ready_steps(s) > 0)
            .count();
        let t0 = Instant::now();
        let result = {
            let mut refs: Vec<&mut Session> = lanes.iter_mut().map(|(_, s, _)| s).collect();
            self.engine.step_batch(&mut refs)
        };
        if occupancy > 0 {
            self.metrics.record_batch(occupancy, t0.elapsed());
            if level > 0 {
                self.metrics.degraded_batches += 1;
            }
        }
        let err = result.err().map(|e| format!("feed failed: {e:#}"));
        let mut done: Vec<(StagedFeed, Json)> = Vec::new();
        for (id, mut s, steps_before) in lanes {
            let steps = s.metrics.steps - steps_before;
            self.metrics.steps_executed += steps as u64;
            self.metrics.audio_seconds += steps as f64 * self.engine.model_cfg.step_seconds();
            let partial = self.engine.partial(&s).map(|t| t.text).unwrap_or_default();
            if err.is_none() {
                self.maybe_checkpoint(id, &mut s);
                self.sessions.insert(id, s);
            } else {
                // Poisoned: discard the session (see the method docs).
                self.last_ckpt.remove(&id);
                let _ = self.retire.send(id);
            }
            let mut i = 0;
            while i < self.staged.len() {
                if self.staged[i].session != id {
                    i += 1;
                    continue;
                }
                let f = self.staged.remove(i);
                let resp = match &err {
                    Some(msg) => err_json(ErrCode::Internal, msg),
                    None => obj(&[
                        ("steps", Json::Num(steps as f64)),
                        ("partial", Json::Str(partial.clone())),
                    ]),
                };
                self.metrics.feed_latency.record(f.enqueued.elapsed());
                done.push((f, resp));
            }
        }
        // Staged feeds whose session vanished from the map (finished
        // from another connection mid-batch): answer, don't hang.
        for f in self.staged.drain(..) {
            done.push((
                f,
                err_json(ErrCode::UnknownSession, "session closed before its batch ran"),
            ));
        }
        self.publish();
        // Fault hook: hold the acknowledgements back to widen races for
        // the chaos suites (no-op unless the reply-delay hook is armed).
        if !done.is_empty() {
            if let Some(delay) = self.engine.fault_reply_delay() {
                std::thread::sleep(delay);
            }
        }
        for (f, resp) in done {
            f.reply.send(resp);
        }
    }

    /// The device loop. Exits when the job channel closes or on
    /// [`Job::Shutdown`] (clean: flushes staged work); **panics** on
    /// [`Job::Die`] (crash simulation) so the drill exercises the same
    /// unwind/rescue path a real worker panic takes ([`run_worker`]).
    /// Borrows the receiver rather than consuming it so the wrapper can
    /// still reach `self.staged` and the queued jobs after an unwind.
    fn run(&mut self, jobs: &mpsc::Receiver<Job>) {
        loop {
            // Enforce the wait budget even under sustained job traffic:
            // a queued message makes recv_timeout return Ok without ever
            // timing out, so an expired partial batch must flush here,
            // not just on the Timeout arm.
            if !self.staged.is_empty() && self.batcher.wait_budget().is_zero() {
                self.flush();
            }
            // Block for the next job; with feeds staged, cap the wait at
            // the batcher's remaining budget so a partial batch still
            // flushes.
            let job = if self.staged.is_empty() {
                match jobs.recv() {
                    Ok(j) => j,
                    Err(_) => break,
                }
            } else {
                match jobs.recv_timeout(self.batcher.wait_budget()) {
                    Ok(j) => j,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        self.flush();
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        self.flush();
                        break;
                    }
                }
            };
            self.depth.fetch_sub(1, Ordering::Relaxed);
            match job {
                Job::Shutdown => {
                    self.flush();
                    break;
                }
                Job::Die => panic!("injected worker kill (kill_worker drill)"),
                other => self.handle(other),
            }
        }
    }

    fn handle(&mut self, job: Job) {
        match job {
            Job::Shutdown | Job::Die => unreachable!("handled by the run loop"),
            Job::Open { id, reply } => {
                let resp = match self.engine.open(false) {
                    Ok(s) => {
                        self.sessions.insert(id, s);
                        self.metrics.sessions_opened += 1;
                        obj(&[("session", Json::Num(id as f64))])
                    }
                    Err(e) => {
                        // The router booked this id at dispatch; un-book
                        // it so failed opens (fallible PJRT open_state)
                        // don't leak assignments or skew load counts.
                        let _ = self.retire.send(id);
                        err_json(ErrCode::Internal, &format!("open failed: {e:#}"))
                    }
                };
                self.publish();
                reply.send(resp);
            }
            Job::Feed { session, samples, enqueued, reply } => {
                match self.sessions.get_mut(&session) {
                    None => {
                        reply.send(err_json(ErrCode::UnknownSession, "unknown session"));
                    }
                    Some(s) => {
                        self.engine.push_audio(s, &samples);
                        self.staged.push(StagedFeed { session, samples, reply, enqueued });
                        // Flush when the batch is full — or when every
                        // open session on this shard is already staged,
                        // since no further lane can arrive before some
                        // staged client unblocks.
                        if self.batcher.push(session)
                            || self.batcher.len() >= self.sessions.len()
                        {
                            self.flush();
                        }
                    }
                }
            }
            Job::Finish { session, reply } => {
                // Any staged work (this session's included) runs first so
                // the transcript covers all fed audio.
                if !self.staged.is_empty() {
                    self.flush();
                }
                self.batcher.remove(session);
                self.last_ckpt.remove(&session);
                // Re-pick the rung for the finish drain itself: the
                // flush above consumed the backlog that justified any
                // degradation, so an uncontended finish always pads out
                // at full quality.
                self.apply_degrade();
                let resp = match self.sessions.remove(&session) {
                    None => err_json(ErrCode::UnknownSession, "unknown session"),
                    Some(mut s) => match self.engine.finish(&mut s) {
                        Ok(t) => {
                            self.metrics.sessions_finished += 1;
                            self.metrics.compute_seconds += s.metrics.compute_s;
                            obj(&[
                                ("text", Json::Str(t.text)),
                                ("score", Json::Num(t.score as f64)),
                                ("rtf", Json::Num(s.metrics.rtf())),
                                ("steps", Json::Num(s.metrics.steps as f64)),
                                ("batch_occupancy", Json::Num(s.metrics.avg_batch_occupancy())),
                                ("degraded_steps", Json::Num(s.metrics.degraded_steps as f64)),
                                (
                                    "degrade_transitions",
                                    Json::Num(s.metrics.degrade_transitions as f64),
                                ),
                            ])
                        }
                        Err(e) => err_json(ErrCode::Internal, &format!("finish failed: {e:#}")),
                    },
                };
                self.publish();
                reply.send(resp);
            }
            Job::Nbest { session, reply } => {
                // Refused up front on engines without a lattice — the
                // session stays open and can still `finish` normally.
                if self.engine.nbest_n() == 0 {
                    reply.send(err_json(
                        ErrCode::BadRequest,
                        "engine built without N-best (serve with --nbest/--rescore)",
                    ));
                    return;
                }
                // From here this is a finish with a richer reply: drain
                // staged work so the lattice covers all fed audio, then
                // pad out uncontended at full quality.
                if !self.staged.is_empty() {
                    self.flush();
                }
                self.batcher.remove(session);
                self.last_ckpt.remove(&session);
                self.apply_degrade();
                let Some(mut s) = self.sessions.remove(&session) else {
                    reply.send(err_json(ErrCode::UnknownSession, "unknown session"));
                    return;
                };
                // The session is consumed from here on; un-book it on
                // the router via the retire back-channel. (Finish
                // retires at dispatch — the router cannot know in
                // advance whether an nbest would be refused, so Nbest
                // retires only once the worker commits to consuming.)
                let _ = self.retire.send(session);
                let resp = match self.engine.nbest(&mut s) {
                    Ok(r) => {
                        self.metrics.sessions_finished += 1;
                        self.metrics.compute_seconds += s.metrics.compute_s;
                        let hyps: Vec<Json> = r
                            .entries
                            .iter()
                            .map(|e| {
                                // The rescored list is re-ranked by
                                // second-pass score, so match each
                                // entry by its word sequence. Without
                                // a second-pass LM the rescore column
                                // equals the exact first-pass score.
                                let second = r
                                    .rescored
                                    .as_ref()
                                    .and_then(|v| v.iter().find(|x| x.words == e.words))
                                    .map(|x| x.second_pass as f64)
                                    .unwrap_or(e.score as f64);
                                obj(&[
                                    ("text", Json::Str(e.text.clone())),
                                    ("score", Json::Num(e.score as f64)),
                                    ("rescore", Json::Num(second)),
                                ])
                            })
                            .collect();
                        obj(&[
                            ("text", Json::Str(r.transcript.text)),
                            ("score", Json::Num(r.transcript.score as f64)),
                            ("steps", Json::Num(s.metrics.steps as f64)),
                            ("nbest", Json::Arr(hyps)),
                        ])
                    }
                    Err(e) => err_json(ErrCode::Internal, &format!("nbest failed: {e:#}")),
                };
                self.publish();
                reply.send(resp);
            }
            Job::Resume { session, reply } => {
                // Flush first so the reported progress covers every feed
                // this worker has accepted (staged audio is un-acked
                // until its flush replies).
                if !self.staged.is_empty() {
                    self.flush();
                }
                let resp = match self.sessions.get(&session) {
                    None => err_json(ErrCode::UnknownSession, "unknown session"),
                    Some(s) => {
                        let partial =
                            self.engine.partial(s).map(|t| t.text).unwrap_or_default();
                        obj(&[
                            ("session", Json::Num(session as f64)),
                            ("steps", Json::Num(s.metrics.steps as f64)),
                            ("frames", Json::Num(s.decode.frames as f64)),
                            ("buffered_samples", Json::Num(s.buffered_samples() as f64)),
                            ("partial", Json::Str(partial)),
                        ])
                    }
                };
                reply.send(resp);
            }
            Job::Config { reply } => {
                reply.send(config_json(&self.engine));
            }
            Job::Evict { ids, token } => {
                // Any session without a feed in flight may leave this
                // shard — mid-utterance ones included: their state
                // travels as a snapshot. Sessions pinned in the batcher
                // (a feed is staged), already gone, or un-snapshottable
                // are *kept* and reported back, so the router can retry
                // them in a later batch.
                let mut moved = Vec::with_capacity(ids.len());
                let mut kept = Vec::new();
                for id in ids {
                    if self.batcher.contains(id) {
                        kept.push(id);
                        continue;
                    }
                    let Some(mut s) = self.sessions.remove(&id) else {
                        kept.push(id);
                        continue;
                    };
                    match self.engine.snapshot(&mut s) {
                        Ok(snap) => {
                            moved.push((id, s.metrics.snapshots_taken as u64, snap.encode()));
                            self.last_ckpt.remove(&id);
                            self.metrics.sessions_migrated_out += 1;
                            // The evicted sessions are no longer this
                            // shard's opens; the adopting shard
                            // re-counts them, so per-shard
                            // opened/finished stay balanced and the
                            // aggregate nets out (−1 here, +1 there).
                            self.metrics.sessions_opened -= 1;
                        }
                        // Un-snapshottable (backend without lane
                        // snapshots): the session stays pinned here.
                        Err(_) => {
                            self.sessions.insert(id, s);
                            kept.push(id);
                        }
                    }
                }
                self.publish();
                let _ =
                    self.migr.send(MigrEvent::Evicted { shard: self.shard, token, moved, kept });
            }
            Job::Adopt { id, snap, returning, token } => {
                let restored = match snap {
                    Some(bytes) => match SessionSnapshot::decode(&bytes)
                        .and_then(|sn| self.engine.restore(&sn))
                    {
                        Ok(s) => Ok(s),
                        // Hand the bytes back for re-adoption elsewhere.
                        Err(_) => Err(Some(bytes)),
                    },
                    // No checkpoint ever existed. For a backend with
                    // snapshot support that means the session never
                    // flushed a feed, so a fresh open under the same id
                    // is exact (nothing was ever acknowledged). For a
                    // backend *without* snapshots it means nothing — the
                    // session may have decoded for minutes — so refuse
                    // rather than silently serve a reset transcript as a
                    // continuation.
                    None if self.engine.backend().supports_lane_snapshots() => {
                        self.engine.open(false).map_err(|_| None)
                    }
                    None => Err(None),
                };
                let outcome = match restored {
                    Ok(s) => {
                        self.last_ckpt.insert(id, s.metrics.steps);
                        self.sessions.insert(id, s);
                        // A bounce-back to the origin shard is not a
                        // migration — don't report phantom adoptions.
                        if !returning {
                            self.metrics.sessions_adopted += 1;
                        }
                        // Adopted sessions count as this shard's opens
                        // (the evicting shard un-counted them), so this
                        // shard's eventual finish balances locally.
                        self.metrics.sessions_opened += 1;
                        Ok(())
                    }
                    Err(back) => Err(back),
                };
                self.publish();
                let _ =
                    self.migr.send(MigrEvent::Adopted { shard: self.shard, token, id, outcome });
            }
            Job::Shed { session } => {
                // Overload shedding: the router only sheds sessions it
                // knows were never fed, so the victim has no staged
                // audio, no batcher lane with work, and nothing a client
                // was promised.
                if self.sessions.remove(&session).is_some() {
                    self.batcher.remove(session);
                    self.last_ckpt.remove(&session);
                    self.metrics.sessions_shed += 1;
                    // Mirror eviction accounting: the session is no
                    // longer this shard's open, so opened/finished stay
                    // balanced (`sessions_shed` keeps the record).
                    self.metrics.sessions_opened -= 1;
                    self.publish();
                }
            }
        }
    }
}

/// Liveness status a worker thread reports on exit.
enum LivenessStatus {
    /// Still running.
    Live,
    /// Exited the loop normally (channel closed or [`Job::Shutdown`]).
    Clean,
    /// Unwound on a panic — spontaneous or the [`Job::Die`] drill.
    Panicked,
}

/// The death-report slot shared between one worker thread and the
/// router's supervisor. The worker's `catch_unwind` wrapper fills it on
/// exit; the router polls `take_panic` between messages and on the
/// supervisor tick (the kill drill is now discovered the same way — no
/// caller ever blocks on this slot). The `reported` flag keeps the
/// polling fast path to one atomic load per shard.
///
/// Besides the rescued orphan jobs, a panic report hands over the
/// worker's *job receiver* itself. The dying thread's drain and its
/// report are not atomic: a job `try_send`-accepted into the queue
/// after the drain but before the router harvests the report used to
/// be destroyed with the channel and bounce to its client. Keeping the
/// receiver alive inside the slot closes that teardown window — the
/// router (the only sender) drains the limbo jobs into the same
/// orphan-replay path, so those clients get their normal replies.
struct WorkerLiveness {
    reported: AtomicBool,
    state: Mutex<(LivenessStatus, Vec<Job>, Option<mpsc::Receiver<Job>>)>,
}

impl WorkerLiveness {
    fn new() -> WorkerLiveness {
        WorkerLiveness {
            reported: AtomicBool::new(false),
            state: Mutex::new((LivenessStatus::Live, Vec::new(), None)),
        }
    }

    /// Post the worker's exit status (+ rescued orphan jobs and the
    /// still-open job receiver on panic).
    fn report(
        &self,
        status: LivenessStatus,
        orphans: Vec<Job>,
        limbo: Option<mpsc::Receiver<Job>>,
    ) {
        *self.state.lock().unwrap() = (status, orphans, limbo);
        self.reported.store(true, Ordering::Release);
    }

    /// Whether an unharvested panic report is waiting (cheap peek: one
    /// atomic load on the fast path).
    fn panicked(&self) -> bool {
        self.reported.load(Ordering::Acquire)
            && matches!(self.state.lock().unwrap().0, LivenessStatus::Panicked)
    }

    /// Harvest a panic report exactly once: the rescued orphans and the
    /// limbo receiver come back on the first call after the worker
    /// reported a panic, and the slot is spent from then on. Clean
    /// exits return `None`.
    fn take_panic(&self) -> Option<(Vec<Job>, Option<mpsc::Receiver<Job>>)> {
        if !self.reported.load(Ordering::Acquire) {
            return None;
        }
        let mut st = self.state.lock().unwrap();
        match st.0 {
            LivenessStatus::Panicked => {
                st.0 = LivenessStatus::Clean;
                Some((std::mem::take(&mut st.1), st.2.take()))
            }
            _ => None,
        }
    }
}

/// Run one worker to completion under `catch_unwind` and report its
/// exit through the shared liveness slot. On a panic — a device-layer
/// bug, the engine's injected-panic fault hook, or the [`Job::Die`]
/// drill, all one path from here on — the wrapper rescues what the
/// dying worker can still prove it owes:
///
/// * its staged feeds (accepted, never acknowledged — their audio
///   arrived after the covering checkpoints, so replaying them against
///   the recovered sessions repeats no audio and loses none), and
/// * client jobs still queued behind the panic (equally
///   un-acknowledged; opens are answered from router state by
///   [`Router::replay`] since recovery re-books them).
///
/// The job *receiver* rides the report into the liveness slot instead
/// of being dropped: the drain above and the report are not atomic, so
/// a job the router `try_send`-accepts in between would otherwise be
/// destroyed by the channel teardown and bounce to its client. The
/// router (the only sender) drains the limbo receiver when it harvests
/// the report, then drops it — from that point every further send
/// fails deterministically and the dead-route path takes over.
fn run_worker(mut worker: Worker, jobs: mpsc::Receiver<Job>, liveness: Arc<WorkerLiveness>) {
    let result = catch_unwind(AssertUnwindSafe(|| worker.run(&jobs)));
    match result {
        Ok(()) => {
            drop(jobs);
            liveness.report(LivenessStatus::Clean, Vec::new(), None);
        }
        Err(_) => {
            let mut orphans: Vec<Job> = worker
                .staged
                .drain(..)
                .map(|f| Job::Feed {
                    session: f.session,
                    samples: f.samples,
                    enqueued: f.enqueued,
                    reply: f.reply,
                })
                .collect();
            // Drain jobs queued behind the panic; router-internal
            // transactions (evict/adopt/shed) are dropped — the router
            // resolves their migration legs itself when it declares
            // this shard dead.
            while let Ok(job) = jobs.try_recv() {
                worker.depth.fetch_sub(1, Ordering::Relaxed);
                match job {
                    j @ (Job::Open { .. }
                    | Job::Feed { .. }
                    | Job::Finish { .. }
                    | Job::Nbest { .. }
                    | Job::Resume { .. }
                    | Job::Config { .. }) => orphans.push(j),
                    Job::Evict { .. }
                    | Job::Adopt { .. }
                    | Job::Shed { .. }
                    | Job::Die
                    | Job::Shutdown => {}
                }
            }
            // Fault hook: widen the drain→report teardown window so the
            // chaos suites can land a job in the limbo channel (no-op
            // unless armed).
            if let Some(delay) = worker.engine.fault_teardown_delay() {
                std::thread::sleep(delay);
            }
            liveness.report(LivenessStatus::Panicked, orphans, Some(jobs));
        }
    }
}

/// One worker's router-side handle.
struct ShardHandle {
    tx: mpsc::SyncSender<Job>,
    depth: Arc<AtomicUsize>,
    /// The worker-published stats cache (non-blocking `stats`).
    cache: Arc<Mutex<ShardSnapshot>>,
    /// Worker generation, bumped by [`Router::mark_dead`]: replies
    /// tagged against an earlier generation are dropped, so a worker
    /// declared dead can never answer a request the router's recovery
    /// path already re-answered (or replayed elsewhere).
    generation: Arc<AtomicU64>,
    /// The worker thread's death-report slot ([`run_worker`]).
    liveness: Arc<WorkerLiveness>,
}

/// One booked session's routing record. `started` flips when the first
/// feed for the session is enqueued to a worker — overload shedding
/// only ever targets sessions that never started (opened, zero audio
/// fed), so nothing a client was promised is ever shed.
#[derive(Clone, Copy)]
struct Booked {
    shard: usize,
    started: bool,
}

/// Router bookkeeping a client job carries: applied only once the job
/// is actually enqueued on a worker (never while it waits on the
/// deferred-retry queue), so assignment state mirrors what a worker
/// will eventually observe.
enum Commit {
    /// Book the session on the dispatch shard.
    Open(u64),
    /// Mark the session started (no longer a shed candidate).
    Feed(u64),
    /// Retire the session's booking and checkpoint.
    Finish(u64),
    /// No router bookkeeping.
    None,
}

impl Commit {
    fn of(job: &Job) -> Commit {
        match job {
            Job::Open { id, .. } => Commit::Open(*id),
            Job::Feed { session, .. } => Commit::Feed(*session),
            Job::Finish { session, .. } => Commit::Finish(*session),
            _ => Commit::None,
        }
    }
}

/// A migration leg the router has issued but not yet seen complete,
/// keyed by its token. Tracked so a worker death mid-leg can be
/// resolved (the completion event will never arrive).
enum Leg {
    /// An evict batch in flight on `shard`; `ids` are parked.
    Evict { shard: usize, ids: Vec<u64> },
    /// An adopt of `id` in flight on shard `to`; `origin` is the shard
    /// the session is still assigned to (a dead shard for recovery
    /// legs), `returning` a bounce-back to the origin.
    Adopt { id: u64, to: usize, origin: usize, returning: bool },
}

/// An adopt the router wants to issue but has not dispatched yet —
/// either freshly produced by an evict completion, or bounced off a
/// full/dead target. Dispatch is retried on every pump.
struct PendingAdopt {
    id: u64,
    snap: Option<Vec<u8>>,
    /// Forced target (a bounce-back to the origin); `None` picks the
    /// least-loaded active shard at dispatch time.
    to: Option<usize>,
    origin: usize,
    returning: bool,
}

/// A client job whose shard queue was full, parked on the deferred
/// retry queue instead of sleeping the router thread. Re-dispatched by
/// the supervisor tick once `not_before` passes.
struct Deferred {
    /// The shard the job last targeted (sessions re-resolve through
    /// `assign` at pump time; this is the fallback for session-less
    /// jobs).
    shard: usize,
    job: Job,
    attempts_left: u32,
    backoff_ms: u64,
    not_before: Instant,
}

/// One in-progress drain ([`ShardPool::drain_worker`]).
struct DrainState {
    deadline: Instant,
    reply: mpsc::Sender<Json>,
    /// Sessions migrated off the draining shard so far.
    migrated: u64,
}

/// One in-progress kill drill ([`ShardPool::kill_worker`]): the reply
/// is deferred until the victim's death report is harvested and every
/// recovery adopt it triggered has resolved.
struct KillState {
    reply: mpsc::Sender<Json>,
    /// Give up waiting for the death report after this instant and
    /// recover as if the staged feeds were lost (wedged worker).
    deadline: Instant,
    /// Recovery adopts still in flight (None until the death report is
    /// harvested and recovery legs are issued).
    pending: Option<usize>,
    /// Sessions recovered for this drill so far.
    recovered: u64,
}

/// Router state: session→shard assignments, per-shard load and
/// liveness, and the latest recovery checkpoint per session — all
/// router-thread-local, so *initial* assignment (`pick`) is a pure
/// function of the request sequence. Migration/recovery placement
/// additionally depends on worker-side flush timing, so placement under
/// load is best-effort — never transcript-affecting, which is the
/// invariant that matters.
struct Router {
    shards: Vec<ShardHandle>,
    /// Per-shard lifecycle. `Active` shards take new sessions;
    /// `Draining` shards keep serving their current sessions but take
    /// no new ones while migration empties them; `Retired` shards shut
    /// down cleanly after a drain; `Dead` shards lost their worker and
    /// had their sessions re-adopted from checkpoints on discovery.
    /// Only `Active` shards are `pick`/`rebalance` targets.
    life: Vec<ShardLifecycle>,
    /// Per-shard count of client jobs bounced with `backpressure`
    /// (router-side; folded into stats snapshots so shed load shows).
    rejected: Vec<u64>,
    assign: HashMap<u64, Booked>,
    open_count: Vec<usize>,
    next_id: u64,
    rebalance_threshold: usize,
    checkpoint_interval: usize,
    /// The pool's overload policy (admission, shedding, retry/backoff,
    /// degrade ladder). Default is fully off.
    overload: OverloadPolicy,
    /// Shed notices that could not be delivered yet: the victim's shard
    /// queue was full at shed time (that is *why* it was shed), so the
    /// notice waits for a free slot. Retried on every loop iteration.
    shed_pending: Vec<(usize, u64)>,
    /// Sessions shed under overload (router-side; surfaced in `stats`).
    shed: u64,
    /// Ids of shed victims, so the owner's *next* request answers the
    /// dedicated `session_shed` code (reopen + resend) instead of the
    /// indistinguishable `unknown_session`. Bounded by the policy's
    /// `shed_memory`; evictions are counted in `shed_evicted`.
    shed_ids: BTreeSet<u64>,
    /// Shed-id notices evicted from the bounded `shed_ids` set
    /// (surfaced in `stats` so the capacity limit is observable).
    shed_evicted: u64,
    /// Opens refused by admission control (surfaced in `stats`).
    admission_rejected: u64,
    /// Spontaneous worker panics the supervisor detected (the kill
    /// drill is counted by its own reply, not here).
    panics_detected: u64,
    /// Freshest encoded [`SessionSnapshot`] per open session, keyed by
    /// its capture sequence number — strictly increasing per session —
    /// so an older in-flight checkpoint can never overwrite a newer
    /// migration snapshot (dropped at finish/retire; unused when
    /// `checkpoint_interval == 0`). Empty bytes are a tombstone: acked
    /// state exists that capture could not cover, so recovery drops the
    /// session instead of restoring something older. What dead-shard
    /// recovery restores from.
    checkpoints: HashMap<u64, (u64, Vec<u8>)>,
    /// Sessions re-adopted off dead shards (surfaced in `stats`).
    recovered: u64,
    /// The workers' un-book back-channel (failed opens, poisoned
    /// batches), drained lazily so load counts stay honest.
    retire_rx: mpsc::Receiver<u64>,
    /// The workers' checkpoint back-channel.
    ckpt_rx: mpsc::Receiver<(u64, u64, Vec<u8>)>,
    /// The workers' migration back-channel: async evict/adopt
    /// completions ([`MigrEvent`]).
    migr_rx: mpsc::Receiver<MigrEvent>,
    /// Template seed for elastic scale-up: [`WorkerSeed::clone_seed`]
    /// mints a fresh seed per [`RouterMsg::PoolAdd`]. `None` when the
    /// backend cannot clone workers (add_worker then errors).
    template: Option<WorkerSeed>,
    /// Sender clones handed to runtime-added workers.
    retire_tx: mpsc::Sender<u64>,
    /// Sender clone handed to runtime-added workers.
    ckpt_tx: mpsc::Sender<(u64, u64, Vec<u8>)>,
    /// Sender clone handed to runtime-added workers.
    migr_tx: mpsc::Sender<MigrEvent>,
    /// Job-queue capacity for runtime-added workers (same as startup).
    queue_depth: usize,
    /// Ceiling on concurrently live workers (`effective_max_workers`).
    max_workers: usize,
    /// Per-drain time budget before the drain aborts.
    drain_deadline: Duration,
    /// In-progress drains, by shard.
    drains: HashMap<usize, DrainState>,
    /// In-progress kill drills, by shard (reply deferred until the
    /// death report is harvested and recovery resolves).
    kills: HashMap<usize, KillState>,
    /// Outstanding migration legs by token.
    legs: HashMap<u64, Leg>,
    /// Token source for migration legs.
    next_token: u64,
    /// Jobs for sessions with a migration leg in flight, queued in
    /// arrival order and replayed on the destination once the leg
    /// resolves — migration is invisible to clients.
    parked: HashMap<u64, Vec<Job>>,
    /// Adopts awaiting dispatch (produced by evict completions, or
    /// bounced off a full target); pumped on every tick and message.
    pending_adopts: Vec<PendingAdopt>,
    /// The deferred-retry queue: jobs whose shard queue was full wait
    /// here (instead of sleeping the router) and re-dispatch on the
    /// supervisor tick, in arrival order, preserving per-session FIFO.
    deferred: VecDeque<Deferred>,
    /// Per-session count of deferred jobs, so later jobs for the same
    /// session queue behind them rather than overtaking.
    deferred_count: HashMap<u64, usize>,
}

impl Router {
    /// Fold pending back-channel traffic into router state: retires
    /// un-book sessions (and drop their checkpoints); checkpoint
    /// messages update the per-session latest (ignored once a session
    /// is no longer booked, so finished sessions cannot leak bytes).
    fn drain_backchannels(&mut self) {
        while let Ok(session) = self.retire_rx.try_recv() {
            if let Some(b) = self.assign.remove(&session) {
                self.open_count[b.shard] = self.open_count[b.shard].saturating_sub(1);
            }
            self.checkpoints.remove(&session);
        }
        while let Ok((id, seq, snap)) = self.ckpt_rx.try_recv() {
            if !self.assign.contains_key(&id) {
                continue;
            }
            // Ignore a checkpoint older than what is already stored —
            // possible when a migration snapshot (captured later) was
            // recorded while this message was still in flight. The
            // capture sequence is strictly increasing, so `<` suffices.
            let stale = matches!(self.checkpoints.get(&id), Some((at, _)) if seq < *at);
            if !stale {
                self.checkpoints.insert(id, (seq, snap));
            }
        }
        let mut events = Vec::new();
        while let Ok(ev) = self.migr_rx.try_recv() {
            events.push(ev);
        }
        for ev in events {
            self.handle_migr_event(ev);
        }
    }

    /// Whether shard `i` still has a worker behind it (active or
    /// draining — a draining shard serves its residents to the end).
    fn is_live(&self, i: usize) -> bool {
        matches!(self.life[i], ShardLifecycle::Active | ShardLifecycle::Draining)
    }

    /// Declare a shard dead: exclude it from routing and advance its
    /// worker generation, invalidating every reply tag taken against
    /// the older generation — any answer the dying worker still
    /// produces for an in-flight request is dropped instead of racing
    /// the recovery path's own answer for the same request.
    fn mark_dead(&mut self, shard: usize) {
        if self.is_live(shard) {
            self.life[shard] = ShardLifecycle::Dead;
            self.shards[shard].generation.fetch_add(1, Ordering::SeqCst);
            // Undeliverable shed notices die with the worker.
            self.shed_pending.retain(|&(s, _)| s != shard);
        }
    }

    /// Full death handling for a shard whose worker is known gone
    /// (panicked per its liveness slot, or its job channel
    /// disconnected): harvest the death report, mark the shard dead,
    /// resolve every migration leg the dead worker was holding, abort
    /// any drain of it, and start asynchronous recovery of its
    /// sessions. Idempotent — a second discovery is a no-op.
    fn handle_death(&mut self, shard: usize) {
        if !self.is_live(shard) {
            return;
        }
        let (orphans, limbo) =
            self.shards[shard].liveness.take_panic().unwrap_or((Vec::new(), None));
        // The kill drill is counted by its own reply, not here.
        if !self.kills.contains_key(&shard) {
            self.panics_detected += 1;
        }
        self.mark_dead(shard);
        self.resolve_legs_for_dead(shard);
        if let Some(d) = self.drains.remove(&shard) {
            let _ = d
                .reply
                .send(err_json(ErrCode::Internal, &format!("shard {shard} died while draining")));
        }
        self.recover(shard, orphans, limbo);
    }

    /// Resolve migration legs that can never complete because `shard`'s
    /// worker died holding them: an evict batch dies with its sessions
    /// still parked (recovery re-adopts them from checkpoints); an
    /// adopt into the dead shard is re-issued elsewhere from the
    /// retained snapshot copy.
    fn resolve_legs_for_dead(&mut self, shard: usize) {
        let tokens: Vec<u64> = self
            .legs
            .iter()
            .filter(|(_, l)| match l {
                Leg::Evict { shard: s, .. } => *s == shard,
                Leg::Adopt { to, .. } => *to == shard,
            })
            .map(|(&t, _)| t)
            .collect();
        for t in tokens {
            match self.legs.remove(&t) {
                Some(Leg::Adopt { id, origin, .. }) => {
                    let snap = self
                        .checkpoints
                        .get(&id)
                        .map(|(_, b)| b.clone())
                        .filter(|b| !b.is_empty());
                    self.pending_adopts.push(PendingAdopt {
                        id,
                        snap,
                        to: None,
                        origin,
                        returning: false,
                    });
                }
                // Evicted sessions stay parked; they are still assigned
                // to the dead shard, so recovery picks them up.
                Some(Leg::Evict { .. }) | None => {}
            }
        }
        for p in &mut self.pending_adopts {
            if p.to == Some(shard) {
                p.to = None;
                p.returning = false;
            }
        }
    }

    /// One supervision pass: harvest death reports posted by worker
    /// `catch_unwind` wrappers ([`run_worker`]) and run the standard
    /// recovery for each — this is how a *spontaneous* worker panic is
    /// discovered (rather than at the next send), and the kill drill
    /// takes the same path. The pass also drives everything deferred:
    /// retry queues, pending adopts, drain progress, and kill drills
    /// whose victim never reported (wedged worker).
    fn supervise(&mut self) {
        let now = Instant::now();
        for i in 0..self.shards.len() {
            if !self.is_live(i) {
                continue;
            }
            if self.shards[i].liveness.panicked() {
                self.handle_death(i);
            } else if matches!(
                self.kills.get(&i),
                Some(k) if k.pending.is_none() && now >= k.deadline
            ) {
                // The victim never reported (wedged in the device
                // backend): proceed as if its staged feeds were lost.
                self.handle_death(i);
            }
        }
        self.pump_deferred();
        self.pump_pending_adopts();
        self.advance_drains();
    }

    /// Shed the oldest *never started* session on a saturated shard
    /// (lowest id — deterministic given the trace), freeing a slot for
    /// load that has audio in flight. Router bookkeeping is dropped
    /// immediately; the worker's notice is delivered when its queue has
    /// room ([`Router::flush_shed`]). Returns false when the policy is
    /// off or every session on the shard already started.
    fn shed_one(&mut self, shard: usize) -> bool {
        if !self.overload.shed_never_started {
            return false;
        }
        // A session with a migration leg in flight is not a candidate:
        // its worker-side state is in transit and the shed notice would
        // chase it across shards.
        let victim = self
            .assign
            .iter()
            .filter(|&(id, b)| b.shard == shard && !b.started && !self.parked.contains_key(id))
            .map(|(&id, _)| id)
            .min();
        let Some(id) = victim else {
            return false;
        };
        self.assign.remove(&id);
        self.open_count[shard] = self.open_count[shard].saturating_sub(1);
        self.checkpoints.remove(&id);
        self.shed += 1;
        self.shed_ids.insert(id);
        // Session ids are monotone, so the *oldest* notices age out of
        // the bounded set — the clients least likely to still come
        // asking. Evictions are counted so the policy's `shed_memory`
        // limit is observable in `stats`.
        while self.shed_ids.len() > self.overload.shed_memory {
            self.shed_ids.pop_first();
            self.shed_evicted += 1;
        }
        self.shed_pending.push((shard, id));
        self.flush_shed();
        true
    }

    /// Best-effort, non-blocking delivery of pending shed notices.
    fn flush_shed(&mut self) {
        let mut i = 0;
        while i < self.shed_pending.len() {
            let (shard, id) = self.shed_pending[i];
            if !self.is_live(shard) {
                self.shed_pending.remove(i);
                continue;
            }
            self.shards[shard].depth.fetch_add(1, Ordering::Relaxed);
            match self.shards[shard].tx.try_send(Job::Shed { session: id }) {
                Ok(()) => {
                    self.shed_pending.remove(i);
                }
                Err(mpsc::TrySendError::Full(_)) => {
                    self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
                    i += 1;
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
                    self.handle_death(shard);
                }
            }
        }
    }

    /// Forward the shutdown control job, accounting its queue-depth
    /// slot. The send may block on *queue space* — a bounded wait on a
    /// live worker draining, never on a worker's answer — which is
    /// acceptable only because shutdown is terminal; every other
    /// control path (including the kill drill, whose target is by
    /// definition suspect) must use a non-blocking `try_send`. Returns
    /// false (and runs death handling) when the worker is gone.
    fn send(&mut self, shard: usize, job: Job) -> bool {
        self.shards[shard].depth.fetch_add(1, Ordering::Relaxed);
        if self.shards[shard].tx.send(job).is_err() {
            self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
            self.handle_death(shard);
            return false;
        }
        true
    }

    /// Router bookkeeping owed the moment a job truly lands on a
    /// worker's queue — deferred alongside the job itself when the job
    /// waits on the retry queue, so a bounced open leaves no phantom
    /// session and a deferred finish retires only once dispatched.
    fn commit(&mut self, shard: usize, c: Commit) {
        match c {
            Commit::Open(id) => {
                self.assign.insert(id, Booked { shard, started: false });
                self.open_count[shard] += 1;
                self.rebalance();
            }
            Commit::Feed(id) => {
                // Audio is now in flight: from here on the session is
                // never a shedding candidate.
                if let Some(b) = self.assign.get_mut(&id) {
                    b.started = true;
                }
            }
            Commit::Finish(id) => {
                self.assign.remove(&id);
                self.checkpoints.remove(&id);
                self.open_count[shard] = self.open_count[shard].saturating_sub(1);
                self.rebalance();
            }
            Commit::None => {}
        }
    }

    /// Forward a client-facing job without ever blocking or sleeping
    /// the router (head-of-line isolation): a full worker queue is a
    /// *suspect* shard — slow, wedged, or merely busy — so the job
    /// parks on the shard's deferred-retry queue for the policy's
    /// bounded retry-with-backoff (`route_retries` × doubling
    /// `route_backoff_ms`, default: none, driven by the supervisor
    /// tick) and then bounces with `backpressure` carrying the policy's
    /// `retry_after_ms` hint. A *dead* shard triggers asynchronous
    /// recovery; jobs for a session mid-recovery (or mid-migration)
    /// park behind its leg and replay on the destination shard.
    fn dispatch(&mut self, shard: usize, job: Job, attempts_left: u32, backoff_ms: u64) {
        // FIFO guard: a session with deferred work must not have a
        // newer job overtake it — the newcomer joins the back of the
        // deferred queue instead.
        if let Some(id) = job.session_id() {
            if self.deferred_count.get(&id).copied().unwrap_or(0) > 0 {
                self.defer(shard, job, attempts_left, backoff_ms, false);
                return;
            }
        }
        self.dispatch_now(shard, job, attempts_left, backoff_ms, false);
    }

    /// The dispatch core, without the FIFO guard — the pump calls this
    /// directly for the *oldest* deferred job of a session (guarding it
    /// against its own siblings would re-queue it behind them). A
    /// re-deferral from here goes to the queue `front` when asked, so a
    /// pumped job keeps its place.
    fn dispatch_now(
        &mut self,
        shard: usize,
        job: Job,
        attempts_left: u32,
        backoff_ms: u64,
        retry_front: bool,
    ) {
        let mut shard = shard;
        let mut job = job;
        // At most two enqueue rounds against *dead* workers (initial +
        // one post-recovery reroute); Full retries are bounded
        // separately by the deferred queue's `attempts_left` budget.
        let mut disconnects = 0;
        while disconnects < 2 {
            if let Some(id) = job.session_id() {
                // A session mid-migration/recovery: queue in arrival
                // order behind its leg; the adopt completion replays.
                if self.parked.contains_key(&id) {
                    self.parked.get_mut(&id).unwrap().push(job);
                    return;
                }
            }
            if !self.is_live(shard) {
                match self.reroute(&job) {
                    Some(s) if self.is_live(s) => shard = s,
                    _ => break,
                }
            }
            // Tag the reply with the target worker's generation: should
            // the router later declare this worker dead, the tag drops
            // any late answer the worker still produces, leaving the
            // recovery path's answer (or replay) the only one.
            if let Some(reply) = job.reply_mut() {
                reply.tag(&self.shards[shard].generation);
            }
            let commit = Commit::of(&job);
            self.shards[shard].depth.fetch_add(1, Ordering::Relaxed);
            match self.shards[shard].tx.try_send(job) {
                Ok(()) => {
                    self.commit(shard, commit);
                    return;
                }
                Err(mpsc::TrySendError::Full(j)) => {
                    self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
                    if attempts_left > 0 {
                        self.defer(shard, j, attempts_left, backoff_ms, retry_front);
                        return;
                    }
                    let mut j = j;
                    self.rejected[shard] += 1;
                    // Make room for the load that bounced: shed the
                    // shard's oldest never-started session (policy-gated).
                    if matches!(j, Job::Feed { .. }) {
                        self.shed_one(shard);
                    }
                    if let Some(reply) = j.reply_mut() {
                        reply.untag();
                        reply.send(backpressure_json(
                            "shard queue full",
                            self.overload.retry_after_ms,
                        ));
                    }
                    return;
                }
                Err(mpsc::TrySendError::Disconnected(j)) => {
                    self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
                    self.handle_death(shard);
                    disconnects += 1;
                    job = j;
                    // Loop: the parked arm (recovery parked the
                    // session) or the dead-shard reroute takes over.
                }
            }
        }
        // Nowhere to route: answer the client.
        let payload = match job.session_id() {
            Some(id) if !self.assign.contains_key(&id) => {
                self.lost_session_json(id, "session lost with its worker")
            }
            _ => err_json(ErrCode::Internal, "shard worker unavailable"),
        };
        if let Some(reply) = job.reply_mut() {
            reply.untag();
            reply.send(payload);
        }
    }

    /// Park a job on the deferred-retry queue; the supervisor tick
    /// re-dispatches it once its backoff passes. `front` re-queues a
    /// pumped job at its old position instead of behind its siblings.
    fn defer(
        &mut self,
        shard: usize,
        mut job: Job,
        attempts_left: u32,
        backoff_ms: u64,
        front: bool,
    ) {
        if let Some(reply) = job.reply_mut() {
            reply.untag();
        }
        if let Some(id) = job.session_id() {
            *self.deferred_count.entry(id).or_insert(0) += 1;
        }
        let d = Deferred {
            shard,
            job,
            attempts_left,
            backoff_ms,
            not_before: Instant::now() + Duration::from_millis(backoff_ms),
        };
        if front {
            self.deferred.push_front(d);
        } else {
            self.deferred.push_back(d);
        }
    }

    /// Re-dispatch deferred jobs whose backoff has passed, in arrival
    /// order. Each retry spends one unit of the attempt budget and
    /// doubles the backoff — exactly the schedule the old in-thread
    /// sleep implemented, without ever sleeping the router. Per-session
    /// FIFO: at most one job per session is released per pump (its
    /// oldest), later siblings hold their queue positions; parked
    /// sessions hold everything until their migration leg resolves.
    fn pump_deferred(&mut self) {
        if self.deferred.is_empty() {
            return;
        }
        let now = Instant::now();
        let mut held: BTreeSet<u64> = BTreeSet::new();
        let pending = std::mem::take(&mut self.deferred);
        let mut rest: VecDeque<Deferred> = VecDeque::with_capacity(pending.len());
        for d in pending {
            let sid = d.job.session_id();
            let blocked =
                sid.is_some_and(|id| held.contains(&id) || self.parked.contains_key(&id));
            if blocked || d.not_before > now {
                if let Some(id) = sid {
                    held.insert(id);
                }
                rest.push_back(d);
                continue;
            }
            if let Some(id) = sid {
                held.insert(id);
                if let Some(c) = self.deferred_count.get_mut(&id) {
                    *c -= 1;
                    if *c == 0 {
                        self.deferred_count.remove(&id);
                    }
                }
            }
            // Route fresh: the session may have migrated (or been
            // lost/shed) while the job waited.
            let target = match sid {
                Some(id) => match self.assign.get(&id) {
                    Some(b) => b.shard,
                    None => {
                        let payload = self.lost_session_json(id, "unknown session");
                        let mut job = d.job;
                        if let Some(reply) = job.reply_mut() {
                            reply.untag();
                            reply.send(payload);
                        }
                        continue;
                    }
                },
                None if matches!(d.job, Job::Open { .. }) => self.pick(),
                None => d.shard,
            };
            self.dispatch_now(
                target,
                d.job,
                d.attempts_left - 1,
                d.backoff_ms.saturating_mul(2),
                true,
            );
        }
        // Re-deferred jobs sit at the queue head (one per session, so
        // head order within a session is preserved); the not-yet-due
        // tail goes back behind them.
        self.deferred.extend(rest);
    }

    /// Re-route a job rescued off a dying worker (a staged feed, or a
    /// client job still queued behind the panic) onto its session's
    /// recovery shard. A rescued feed's audio was pushed *after* the
    /// checkpoint its session recovered from, so the replay repeats no
    /// audio — the client's pending request answers normally instead of
    /// bouncing with `internal`/`unknown_session`. A rescued *open* was
    /// never processed by the dead worker, but recovery already
    /// re-booked its id (fresh open on a survivor — nothing was ever
    /// acknowledged for it), so it is answered from router state rather
    /// than opening a duplicate.
    fn replay(&mut self, job: Job) {
        match job {
            Job::Open { id, mut reply } => {
                reply.untag();
                reply.send(if self.assign.contains_key(&id) {
                    obj(&[("session", Json::Num(id as f64))])
                } else {
                    err_json(ErrCode::Internal, "session lost with its worker")
                });
            }
            mut job => {
                if let Some(id) = job.session_id() {
                    // The session's recovery adopt is still in flight:
                    // queue behind it; the completion replays in order.
                    if self.parked.contains_key(&id) {
                        self.parked.get_mut(&id).unwrap().push(job);
                        return;
                    }
                }
                match self.reroute(&job) {
                    Some(shard) => {
                        let backoff = self.overload.route_backoff_ms.max(1);
                        self.dispatch(shard, job, self.overload.route_retries, backoff);
                    }
                    None => {
                        let payload = match job.session_id() {
                            Some(id) => self.lost_session_json(id, "session lost with its worker"),
                            None => err_json(ErrCode::Internal, "shard worker unavailable"),
                        };
                        if let Some(reply) = job.reply_mut() {
                            reply.untag();
                            reply.send(payload);
                        }
                    }
                }
            }
        }
    }

    /// The error payload for a session this router has no assignment
    /// for: shed victims get the dedicated `session_shed` code plus a
    /// reopen hint; anything else stays `unknown_session`.
    fn lost_session_json(&self, session: u64, detail: &str) -> Json {
        if self.shed_ids.contains(&session) {
            err_json(
                ErrCode::SessionShed,
                "session shed under overload before decoding started; reopen and resend",
            )
        } else {
            err_json(ErrCode::UnknownSession, detail)
        }
    }

    /// Where to retry a job after recovery: its session's new shard, or
    /// the least-loaded live shard for session-less jobs. `None` when
    /// the session was lost or every worker is dead.
    fn reroute(&self, job: &Job) -> Option<usize> {
        if let Some(id) = job.session_id() {
            return self.assign.get(&id).map(|b| b.shard);
        }
        let s = self.pick();
        self.is_live(s).then_some(s)
    }

    /// Least-loaded *active* shard by open sessions, lowest index on
    /// ties — deterministic given the open/finish sequence. Draining
    /// shards take no new placements. Falls back to shard 0 only when
    /// no worker is active (the request then bounces with `internal`
    /// rather than silently hanging).
    fn pick(&self) -> usize {
        (0..self.shards.len())
            .filter(|&i| self.life[i] == ShardLifecycle::Active)
            .min_by_key(|&i| (self.open_count[i], i))
            .unwrap_or(0)
    }

    /// The lowest-index live shard (serves `config`).
    fn first_live(&self) -> usize {
        (0..self.shards.len()).find(|&i| self.is_live(i)).unwrap_or(0)
    }

    /// Drop every trace of a session and answer any jobs parked behind
    /// its migration/recovery leg with the lost-session payload.
    fn lose_session(&mut self, id: u64, detail: &str) {
        if let Some(b) = self.assign.remove(&id) {
            self.open_count[b.shard] = self.open_count[b.shard].saturating_sub(1);
        }
        self.checkpoints.remove(&id);
        self.deferred_count.remove(&id);
        if let Some(jobs) = self.parked.remove(&id) {
            for mut job in jobs {
                let payload = self.lost_session_json(id, detail);
                if let Some(reply) = job.reply_mut() {
                    reply.untag();
                    reply.send(payload);
                }
            }
        }
    }

    /// Whether a session has an adopt leg in flight or queued — such a
    /// session must not be re-adopted by recovery or picked for another
    /// migration until its current leg resolves.
    fn migrating(&self, id: u64) -> bool {
        self.pending_adopts.iter().any(|p| p.id == id)
            || self
                .legs
                .values()
                .any(|l| matches!(l, Leg::Adopt { id: lid, .. } if *lid == id))
    }

    /// Queue re-adoption of every session assigned to a dead shard,
    /// restoring from the latest checkpoint when one exists. A session
    /// that never shipped a checkpoint re-opens fresh when
    /// checkpointing is enabled *and* the backend supports snapshots —
    /// it then provably never flushed a feed, so nothing was ever
    /// acknowledged for it. Otherwise (checkpointing disabled, or a
    /// snapshot-less backend, where "no checkpoint" proves nothing) it
    /// is dropped — later ops report `unknown_session` rather than
    /// silently serving a reset transcript as a continuation.
    ///
    /// Recovery is *pipelined*: adopts queue as [`PendingAdopt`]s and
    /// dispatch without waiting for worker replies, so a dead shard
    /// never stalls routing for the live ones. `orphans` are the client
    /// jobs rescued off the dying worker's queue, `limbo` whatever was
    /// still in its channel when the death report posted — both replay
    /// onto the sessions' recovery shards (parking behind in-flight
    /// adopts), so the clients' pending requests answer normally.
    fn recover(
        &mut self,
        dead_shard: usize,
        orphans: Vec<Job>,
        limbo: Option<mpsc::Receiver<Job>>,
    ) {
        // Pull in checkpoints the worker shipped just before dying.
        self.drain_backchannels();
        let mut orphans = orphans;
        if let Some(rx) = limbo {
            // Jobs enqueued in the teardown window between the panic
            // and the report: drain them here so their clients get the
            // same replay treatment as the rescued staged feeds.
            while let Ok(job) = rx.try_recv() {
                self.shards[dead_shard].depth.fetch_sub(1, Ordering::Relaxed);
                match job {
                    Job::Open { .. }
                    | Job::Feed { .. }
                    | Job::Finish { .. }
                    | Job::Nbest { .. }
                    | Job::Resume { .. }
                    | Job::Config { .. } => orphans.push(job),
                    // Internal jobs have no client waiting; legs were
                    // already resolved by `resolve_legs_for_dead`.
                    _ => {}
                }
            }
        }
        let mut ids: Vec<u64> = self
            .assign
            .iter()
            .filter_map(|(&id, b)| (b.shard == dead_shard).then_some(id))
            .collect();
        ids.sort_unstable();
        let mut pends = 0usize;
        for id in ids {
            // A session with an adopt already in flight (it was mid-
            // migration when its origin died) resolves through that
            // leg's completion, not through recovery.
            if self.migrating(id) {
                continue;
            }
            self.parked.entry(id).or_default();
            let snap = self.checkpoints.get(&id).map(|(_, bytes)| bytes.clone());
            let lost = (snap.is_none() && self.checkpoint_interval == 0)
                // A tombstone (empty bytes) means acked state existed
                // that capture could not cover: drop rather than
                // restore stale state or reset the session.
                || matches!(&snap, Some(bytes) if bytes.is_empty());
            if lost {
                self.lose_session(id, "session lost with its worker");
                continue;
            }
            self.pending_adopts.push(PendingAdopt {
                id,
                snap,
                to: None,
                origin: dead_shard,
                returning: false,
            });
            pends += 1;
        }
        if let Some(k) = self.kills.get_mut(&dead_shard) {
            k.pending = Some(pends);
        }
        for job in orphans {
            self.replay(job);
        }
        self.pump_pending_adopts();
        self.finish_kill(dead_shard);
    }

    /// Migrate sessions off the hottest shard when the open-session
    /// imbalance reaches the threshold — live, mid-utterance sessions
    /// included (their state travels as snapshots; only sessions with a
    /// feed in flight are briefly pinned). Rounds are serialized by the
    /// in-flight leg guard rather than by blocking: a new round starts
    /// only once the previous one's legs have resolved, so the async
    /// open-count lag can never trigger an over-migration storm.
    fn rebalance(&mut self) {
        let thr = self.rebalance_threshold;
        if thr == 0 || self.shards.len() < 2 {
            return;
        }
        if !self.legs.is_empty() || !self.pending_adopts.is_empty() {
            return;
        }
        // Only active shards donate and receive: draining shards empty
        // through their own path, dead ones have no queue.
        let Some(hot) = (0..self.shards.len())
            .filter(|&i| self.life[i] == ShardLifecycle::Active)
            .max_by_key(|&i| self.open_count[i])
        else {
            return;
        };
        let cold = self.pick();
        if self.life[cold] != ShardLifecycle::Active || hot == cold {
            return;
        }
        let diff = self.open_count[hot] - self.open_count[cold];
        if diff < thr {
            return;
        }
        let want = diff / 2;
        if want == 0 {
            return;
        }
        let mut ids: Vec<u64> = self
            .assign
            .iter()
            .filter_map(|(&id, b)| (b.shard == hot).then_some(id))
            .filter(|&id| {
                !self.parked.contains_key(&id)
                    && !self.deferred_count.contains_key(&id)
                    && !self.migrating(id)
            })
            .collect();
        ids.sort_unstable();
        ids.truncate(want);
        if !ids.is_empty() {
            self.issue_evict(hot, ids);
        }
    }

    /// Issue an evict batch to `shard`, parking the named sessions so
    /// their client jobs queue in order behind the migration. The
    /// worker answers on the migration back-channel; nothing blocks.
    fn issue_evict(&mut self, shard: usize, ids: Vec<u64>) {
        let token = self.next_token;
        self.next_token += 1;
        let fresh: Vec<u64> = ids
            .iter()
            .copied()
            .filter(|id| !self.parked.contains_key(id))
            .collect();
        for &id in &fresh {
            self.parked.insert(id, Vec::new());
        }
        self.shards[shard].depth.fetch_add(1, Ordering::Relaxed);
        match self.shards[shard].tx.try_send(Job::Evict { ids: ids.clone(), token }) {
            Ok(()) => {
                self.legs.insert(token, Leg::Evict { shard, ids });
            }
            Err(mpsc::TrySendError::Full(_)) => {
                // The hot shard's queue is full: skip this round; the
                // next rebalance trigger (or supervisor tick) retries.
                self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
                for id in fresh {
                    self.unpark(id);
                }
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
                for id in fresh {
                    if let Some(jobs) = self.parked.remove(&id) {
                        debug_assert!(jobs.is_empty());
                    }
                }
                self.handle_death(shard);
            }
        }
    }

    /// Dispatch queued adopts to their targets (forced origin for a
    /// bounce-back, least-loaded active shard otherwise). An adopt that
    /// cannot dispatch (full target queue, no active shard) stays
    /// queued for the next pump; a dead forced target falls back to a
    /// fresh pick on a later pump via `resolve_legs_for_dead`.
    fn pump_pending_adopts(&mut self) {
        let pends = std::mem::take(&mut self.pending_adopts);
        for p in pends {
            if !self.assign.contains_key(&p.id) {
                // The session finished or was lost while the adopt
                // waited (e.g. a shed) — nothing to place.
                if self.life[p.origin] == ShardLifecycle::Dead {
                    self.note_kill_leg_resolved(p.origin);
                }
                self.lose_session(p.id, "unknown session");
                continue;
            }
            let to = match p.to {
                Some(t) if self.is_live(t) => Some(t),
                Some(_) => None,
                None => {
                    let t = self.pick();
                    (self.life[t] == ShardLifecycle::Active).then_some(t)
                }
            };
            let Some(to) = to else {
                if self.life[p.origin] == ShardLifecycle::Dead && p.to.is_none() {
                    // No active shard left to recover onto.
                    let origin = p.origin;
                    self.note_kill_leg_resolved(origin);
                    self.lose_session(p.id, "session lost with its worker");
                } else if p.to.is_some() {
                    // Bounce-back target died: the session's only state
                    // is the snapshot we still hold — requeue for a
                    // fresh pick.
                    self.pending_adopts.push(PendingAdopt { to: None, returning: false, ..p });
                } else {
                    self.pending_adopts.push(p);
                }
                continue;
            };
            let token = self.next_token;
            self.next_token += 1;
            self.parked.entry(p.id).or_default();
            self.shards[to].depth.fetch_add(1, Ordering::Relaxed);
            let job = Job::Adopt { id: p.id, snap: p.snap.clone(), returning: p.returning, token };
            match self.shards[to].tx.try_send(job) {
                Ok(()) => {
                    self.legs.insert(
                        token,
                        Leg::Adopt { id: p.id, to, origin: p.origin, returning: p.returning },
                    );
                }
                Err(mpsc::TrySendError::Full(_)) => {
                    self.shards[to].depth.fetch_sub(1, Ordering::Relaxed);
                    self.pending_adopts.push(p);
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    self.shards[to].depth.fetch_sub(1, Ordering::Relaxed);
                    self.pending_adopts.push(p);
                    self.handle_death(to);
                }
            }
        }
    }

    /// Apply a migration completion event from a worker back-channel.
    fn handle_migr_event(&mut self, ev: MigrEvent) {
        match ev {
            MigrEvent::Evicted { shard, token, moved, kept } => {
                if self.legs.remove(&token).is_none() {
                    // Stale: the shard was declared dead mid-leg and
                    // the leg already resolved.
                    return;
                }
                for id in kept {
                    self.unpark(id);
                }
                for (id, seq, bytes) in moved {
                    if !self.assign.contains_key(&id) {
                        // Un-booked while the evict ran (retired by the
                        // worker, or shed): answer anything parked and
                        // drop the state.
                        self.lose_session(id, "unknown session");
                        continue;
                    }
                    // The evicted snapshot is the freshest state this
                    // session has — it doubles as its recovery
                    // checkpoint (when checkpointing is enabled at all).
                    if self.checkpoint_interval > 0 {
                        self.checkpoints.insert(id, (seq, bytes.clone()));
                    }
                    self.pending_adopts.push(PendingAdopt {
                        id,
                        snap: Some(bytes),
                        to: None,
                        origin: shard,
                        returning: false,
                    });
                }
                self.pump_pending_adopts();
            }
            MigrEvent::Adopted { shard, token, id, outcome } => {
                let Some(Leg::Adopt { origin, returning, .. }) = self.legs.remove(&token) else {
                    return;
                };
                match outcome {
                    Ok(()) => {
                        let started = self.assign.get(&id).is_some_and(|b| b.started);
                        let prior = self.assign.insert(id, Booked { shard, started });
                        match prior {
                            Some(b) if b.shard != shard => {
                                self.open_count[b.shard] =
                                    self.open_count[b.shard].saturating_sub(1);
                                self.open_count[shard] += 1;
                            }
                            Some(_) => {}
                            None => self.open_count[shard] += 1,
                        }
                        if self.life[origin] == ShardLifecycle::Dead {
                            self.recovered += 1;
                            if let Some(k) = self.kills.get_mut(&origin) {
                                k.recovered += 1;
                            }
                            self.note_kill_leg_resolved(origin);
                        } else if !returning && origin != shard {
                            if let Some(d) = self.drains.get_mut(&origin) {
                                d.migrated += 1;
                            }
                        }
                        self.unpark(id);
                    }
                    Err(back) => {
                        if returning {
                            // Lost on both legs: unrecoverable.
                            self.lose_session(id, "session lost in migration");
                        } else if self.life[origin] == ShardLifecycle::Dead {
                            // A recovery adopt was refused (snapshot-
                            // less backend): the session is gone.
                            self.note_kill_leg_resolved(origin);
                            self.lose_session(id, "session lost with its worker");
                        } else {
                            // Target refused or handed the snapshot
                            // back: bounce the session to its origin.
                            let snap = back.or_else(|| {
                                self.checkpoints
                                    .get(&id)
                                    .map(|(_, b)| b.clone())
                                    .filter(|b| !b.is_empty())
                            });
                            match snap {
                                None => self.lose_session(id, "session lost in migration"),
                                Some(bytes) => self.pending_adopts.push(PendingAdopt {
                                    id,
                                    snap: Some(bytes),
                                    to: Some(origin),
                                    origin,
                                    returning: true,
                                }),
                            }
                        }
                        self.pump_pending_adopts();
                    }
                }
            }
        }
    }

    /// One recovery adopt for a killed shard resolved: decrement its
    /// drill's pending count and answer the drill when it hits zero.
    fn note_kill_leg_resolved(&mut self, origin: usize) {
        if let Some(k) = self.kills.get_mut(&origin) {
            if let Some(p) = k.pending.as_mut() {
                *p = p.saturating_sub(1);
            }
        }
        self.finish_kill(origin);
    }

    /// Answer a kill drill whose recovery has fully resolved.
    fn finish_kill(&mut self, shard: usize) {
        let done = matches!(self.kills.get(&shard), Some(k) if k.pending == Some(0));
        if done {
            let k = self.kills.remove(&shard).unwrap();
            let _ = k.reply.send(obj(&[
                ("killed", Json::Num(shard as f64)),
                ("recovered", Json::Num(k.recovered as f64)),
            ]));
        }
    }

    /// Release a session's parked jobs back into routing, in arrival
    /// order, after its migration/recovery leg resolved.
    fn unpark(&mut self, id: u64) {
        let Some(jobs) = self.parked.remove(&id) else {
            return;
        };
        for mut job in jobs {
            match self.reroute(&job) {
                Some(shard) => {
                    let backoff = self.overload.route_backoff_ms.max(1);
                    self.dispatch(shard, job, self.overload.route_retries, backoff);
                }
                None => {
                    let payload = self.lost_session_json(id, "session lost with its worker");
                    if let Some(reply) = job.reply_mut() {
                        reply.untag();
                        reply.send(payload);
                    }
                }
            }
        }
    }

    /// Advance every in-progress drain (supervisor tick).
    fn advance_drains(&mut self) {
        let shards: Vec<usize> = self.drains.keys().copied().collect();
        for shard in shards {
            self.advance_drain(shard);
        }
    }

    /// One drain step for `shard`: retire it once empty, revert it to
    /// active past the deadline, otherwise evict the next resident
    /// batch — at most one batch in flight per tick, so the pool keeps
    /// serving while the drain pipelines.
    fn advance_drain(&mut self, shard: usize) {
        if self.life[shard] != ShardLifecycle::Draining {
            return;
        }
        let busy = self.legs.values().any(|l| match l {
            Leg::Evict { shard: s, .. } => *s == shard,
            Leg::Adopt { to, origin, .. } => *to == shard || *origin == shard,
        }) || self
            .pending_adopts
            .iter()
            .any(|p| p.origin == shard || p.to == Some(shard));
        let mut resident: Vec<u64> = self
            .assign
            .iter()
            .filter_map(|(&id, b)| (b.shard == shard).then_some(id))
            .collect();
        resident.sort_unstable();
        // Deferred jobs never pin a drain: session jobs re-resolve
        // through `assign` at pump time (and a session with deferred
        // work is still resident here anyway), session-less ones
        // reroute off a retired shard on dispatch.
        if resident.is_empty() && !busy {
            let d = self.drains.remove(&shard).unwrap();
            // Retire *before* the shutdown send so `send`'s failure
            // path cannot re-mark the shard (it is no longer live).
            self.life[shard] = ShardLifecycle::Retired;
            self.shards[shard].depth.fetch_add(1, Ordering::Relaxed);
            if self.shards[shard].tx.try_send(Job::Shutdown).is_err() {
                self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
            }
            let _ = d.reply.send(obj(&[
                ("shard", Json::Num(shard as f64)),
                ("state", Json::Str("retired".into())),
                ("migrated", Json::Num(d.migrated as f64)),
            ]));
            return;
        }
        if Instant::now() >= self.drains[&shard].deadline {
            let d = self.drains.remove(&shard).unwrap();
            self.life[shard] = ShardLifecycle::Active;
            let _ = d.reply.send(err_json(
                ErrCode::Internal,
                &format!("drain deadline exceeded on shard {shard}; reverted to active"),
            ));
            return;
        }
        if busy {
            return;
        }
        let ids: Vec<u64> = resident
            .into_iter()
            .filter(|&id| {
                !self.parked.contains_key(&id)
                    && !self.deferred_count.contains_key(&id)
                    && !self.migrating(id)
            })
            .take(DRAIN_EVICT_BATCH)
            .collect();
        if !ids.is_empty() {
            self.issue_evict(shard, ids);
        }
    }

    /// Add a worker to the pool at runtime, seeded from the engine
    /// template shard 0 minted at startup. Answers the `pool add`
    /// request with the new shard index and live worker count.
    fn add_worker(&mut self, reply: &mpsc::Sender<Json>) {
        let live = (0..self.shards.len()).filter(|&i| self.is_live(i)).count();
        if live >= self.max_workers {
            let _ = reply.send(err_json(
                ErrCode::BadRequest,
                &format!("pool is at max_workers ({})", self.max_workers),
            ));
            return;
        }
        let Some(seed) = self.template.as_ref().and_then(|t| t.clone_seed()) else {
            let _ = reply.send(err_json(
                ErrCode::BadRequest,
                "backend cannot clone workers (no elastic scale-up)",
            ));
            return;
        };
        let shard = self.shards.len();
        let (tx, rx) = mpsc::sync_channel::<Job>(self.queue_depth);
        let depth = Arc::new(AtomicUsize::new(0));
        let cache = Arc::new(Mutex::new(ShardSnapshot::empty(shard)));
        let liveness = Arc::new(WorkerLiveness::new());
        let worker_depth = Arc::clone(&depth);
        let worker_cache = Arc::clone(&cache);
        let worker_live = Arc::clone(&liveness);
        let worker_retire = self.retire_tx.clone();
        let worker_ckpt = self.ckpt_tx.clone();
        let worker_migr = self.migr_tx.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("asrpu-shard-{shard}"))
            .spawn(move || {
                let worker = Worker::new(
                    shard,
                    seed.into_engine(),
                    worker_depth,
                    worker_retire,
                    worker_ckpt,
                    worker_migr,
                    worker_cache,
                );
                run_worker(worker, rx, worker_live)
            });
        if spawned.is_err() {
            let _ = reply.send(err_json(
                ErrCode::Internal,
                &format!("spawning shard {shard} failed"),
            ));
            return;
        }
        self.shards.push(ShardHandle {
            tx,
            depth,
            cache,
            generation: Arc::new(AtomicU64::new(0)),
            liveness,
        });
        self.life.push(ShardLifecycle::Active);
        self.open_count.push(0);
        self.rejected.push(0);
        let _ = reply.send(obj(&[
            ("shard", Json::Num(shard as f64)),
            ("workers", Json::Num((live + 1) as f64)),
        ]));
    }

    /// The `pool status` payload: pool-wide worker counts plus one
    /// entry per shard with its lifecycle, session count and queue
    /// depth (dead and retired shards included, so operators see the
    /// full history of the pool's shape).
    fn pool_status_json(&self) -> Json {
        let shards: Vec<Json> = (0..self.shards.len())
            .map(|i| {
                obj(&[
                    ("shard", Json::Num(i as f64)),
                    ("lifecycle", Json::Str(self.life[i].as_str().to_string())),
                    ("sessions", Json::Num(self.open_count[i] as f64)),
                    ("queue", Json::Num(self.shards[i].depth.load(Ordering::Relaxed) as f64)),
                ])
            })
            .collect();
        let live = (0..self.shards.len()).filter(|&i| self.is_live(i)).count();
        obj(&[
            ("workers", Json::Num(live as f64)),
            ("max_workers", Json::Num(self.max_workers as f64)),
            ("draining", Json::Num(self.drains.len() as f64)),
            ("shards", Json::Arr(shards)),
        ])
    }

    /// Aggregate the worker-published stats caches — no worker queue is
    /// touched, so a `stats` poll never waits behind a batch flush
    /// (this replaces the broadcast-then-collect snapshot probe). Only
    /// live queue depth is read fresh; dead shards are omitted and
    /// surface through the `responding` count.
    fn snapshot(&self) -> ShardMetrics {
        let mut shards = Vec::with_capacity(self.shards.len());
        for (i, h) in self.shards.iter().enumerate() {
            if !self.is_live(i) {
                continue;
            }
            let mut snap = h.cache.lock().unwrap().clone();
            snap.queue_depth = h.depth.load(Ordering::Relaxed);
            snap.lifecycle = self.life[i];
            // Workers can't see router-side bounces; fold them in here
            // so `rejected` in summaries reflects shed load.
            snap.serve.rejected_backpressure += self.rejected[i];
            shards.push(snap);
        }
        ShardMetrics { shards }
    }
}

/// Render the aggregated stats payload (the `stats` op's response):
/// a merged summary plus one entry per responding shard. `workers` is
/// the configured pool size; a `responding` count below it surfaces
/// dead workers instead of silently shrinking the report; `recovered`
/// counts sessions re-adopted off dead shards. The overload/liveness
/// counters ride along: per shard the current degrade rung, degraded
/// batch count, shed sessions and heartbeat; pool-wide the admission
/// rejections, sessions shed, and supervisor-detected panics.
fn stats_json(m: &ShardMetrics, workers: usize, r: &Router) -> Json {
    let shards: Vec<Json> = m
        .shards
        .iter()
        .map(|s| {
            obj(&[
                ("shard", Json::Num(s.shard as f64)),
                ("sessions", Json::Num(s.open_sessions as f64)),
                ("queue", Json::Num(s.queue_depth as f64)),
                ("adopted", Json::Num(s.serve.sessions_adopted as f64)),
                ("migrated", Json::Num(s.serve.sessions_migrated_out as f64)),
                ("checkpoints", Json::Num(s.serve.checkpoints_published as f64)),
                ("degrade_level", Json::Num(s.degrade_level as f64)),
                ("degraded_batches", Json::Num(s.serve.degraded_batches as f64)),
                ("shed", Json::Num(s.serve.sessions_shed as f64)),
                ("heartbeats", Json::Num(s.heartbeats as f64)),
                ("lifecycle", Json::Str(s.lifecycle.as_str().to_string())),
                ("summary", Json::Str(s.serve.summary())),
            ])
        })
        .collect();
    obj(&[
        // The human-readable line: aggregate counters plus a per-shard
        // sessions/queue/rtf appendix (ShardMetrics::summary).
        ("summary", Json::Str(m.summary())),
        ("workers", Json::Num(workers as f64)),
        ("responding", Json::Num(m.shards.len() as f64)),
        ("imbalance", Json::Num(m.imbalance() as f64)),
        ("recovered", Json::Num(r.recovered as f64)),
        ("rejected_admission", Json::Num(r.admission_rejected as f64)),
        ("shed", Json::Num(r.shed as f64)),
        ("shed_evicted", Json::Num(r.shed_evicted as f64)),
        ("panics_detected", Json::Num(r.panics_detected as f64)),
        (
            "retired",
            Json::Num(r.life.iter().filter(|&&l| l == ShardLifecycle::Retired).count() as f64),
        ),
        ("shards", Json::Arr(shards)),
    ])
}

/// The router loop: serializes assignment decisions, forwards work,
/// answers session-less requests itself, owns the checkpoint store
/// dead-shard recovery restores from, and doubles as the worker
/// supervisor — between messages (and on a short idle timeout) it
/// harvests death reports, so a spontaneously-panicked worker is
/// recovered even when no client traffic would have touched it.
fn router_loop(jobs: mpsc::Receiver<RouterMsg>, mut r: Router) {
    loop {
        let msg = match jobs.recv_timeout(SUPERVISE_INTERVAL) {
            Ok(m) => m,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                r.supervise();
                r.flush_shed();
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        r.supervise();
        r.drain_backchannels();
        r.flush_shed();
        match msg {
            RouterMsg::Open { reply } => {
                let shard = r.pick();
                if r.life[shard] != ShardLifecycle::Active {
                    let _ = reply.send(err_json(
                        ErrCode::Internal,
                        "no active worker to open a session on",
                    ));
                    continue;
                }
                // Admission control: refuse new sessions rather than
                // queue them once every active shard is at the policy's
                // limit (`pick` is least-loaded, so the picked shard
                // being full means all of them are).
                let limit = r.overload.admit_sessions_per_shard;
                if limit > 0 && r.open_count[shard] >= limit {
                    r.admission_rejected += 1;
                    let _ = reply.send(backpressure_json(
                        "session admission limit reached",
                        r.overload.retry_after_ms,
                    ));
                    continue;
                }
                let id = r.next_id;
                r.next_id += 1;
                // The assignment commits only once the job is enqueued
                // (`Commit::Open`) — a bounced open leaves no phantom
                // session behind. A worker-side engine.open() failure
                // after enqueue (fallible PJRT open_state) comes back
                // as a retire notification and is un-booked on the next
                // drain.
                let job = Job::Open { id, reply: Reply::new(reply) };
                let backoff = r.overload.route_backoff_ms.max(1);
                r.dispatch(shard, job, r.overload.route_retries, backoff);
            }
            RouterMsg::Feed { session, samples, enqueued, reply } => {
                match r.assign.get(&session).map(|b| b.shard) {
                    None => {
                        let _ = reply.send(r.lost_session_json(session, "unknown session"));
                    }
                    Some(shard) => {
                        // A bounce answers the client itself; nothing
                        // reached the shard, so ordering is preserved.
                        // `started` flips at enqueue (`Commit::Feed`).
                        let job = Job::Feed {
                            session,
                            samples,
                            enqueued,
                            reply: Reply::new(reply),
                        };
                        let backoff = r.overload.route_backoff_ms.max(1);
                        r.dispatch(shard, job, r.overload.route_retries, backoff);
                    }
                }
            }
            RouterMsg::Finish { session, reply } => match r.assign.get(&session).map(|b| b.shard) {
                None => {
                    let _ = reply.send(r.lost_session_json(session, "unknown session"));
                }
                Some(shard) => {
                    // The session retires only once the finish is
                    // actually enqueued (`Commit::Finish`, possibly on
                    // a recovery target); on a bounce the client
                    // retries against a still-open session.
                    let job = Job::Finish { session, reply: Reply::new(reply) };
                    let backoff = r.overload.route_backoff_ms.max(1);
                    r.dispatch(shard, job, r.overload.route_retries, backoff);
                }
            },
            RouterMsg::Resume { session, reply } => match r.assign.get(&session).map(|b| b.shard) {
                None => {
                    let _ = reply.send(r.lost_session_json(
                        session,
                        "unknown session (never opened, finished, or lost)",
                    ));
                }
                Some(shard) => {
                    let job = Job::Resume { session, reply: Reply::new(reply) };
                    let backoff = r.overload.route_backoff_ms.max(1);
                    r.dispatch(shard, job, r.overload.route_retries, backoff);
                }
            },
            RouterMsg::Nbest { session, reply } => match r.assign.get(&session).map(|b| b.shard) {
                None => {
                    let _ = reply.send(r.lost_session_json(session, "unknown session"));
                }
                Some(shard) => {
                    // Unlike Finish, the assignment is NOT retired at
                    // dispatch: a worker refusing the op (engine built
                    // without N-best) leaves the session open, so the
                    // un-booking rides the retire back-channel instead,
                    // sent by the worker once it consumes the session.
                    let job = Job::Nbest { session, reply: Reply::new(reply) };
                    let backoff = r.overload.route_backoff_ms.max(1);
                    r.dispatch(shard, job, r.overload.route_retries, backoff);
                }
            },
            RouterMsg::Stats { reply } => {
                let workers = r
                    .life
                    .iter()
                    .filter(|&&l| l != ShardLifecycle::Retired)
                    .count();
                let snap = r.snapshot();
                let _ = reply.send(stats_json(&snap, workers, &r));
            }
            RouterMsg::Config { reply } => {
                let shard = r.first_live();
                let backoff = r.overload.route_backoff_ms.max(1);
                let job = Job::Config { reply: Reply::new(reply) };
                r.dispatch(shard, job, r.overload.route_retries, backoff);
            }
            RouterMsg::Kill { shard, reply } => {
                if shard >= r.shards.len() {
                    let _ = reply.send(err_json(
                        ErrCode::BadRequest,
                        &format!("no such shard {shard}"),
                    ));
                } else if !r.is_live(shard) {
                    // Already dead or retired: nothing to drill.
                    let _ = reply.send(obj(&[
                        ("killed", Json::Num(shard as f64)),
                        ("recovered", Json::Num(0.0)),
                    ]));
                } else if r.kills.contains_key(&shard) {
                    let _ = reply.send(err_json(
                        ErrCode::BadRequest,
                        &format!("kill already in progress on shard {shard}"),
                    ));
                } else {
                    // The drill *is* an injected panic: the worker
                    // panics on the Die job, its catch_unwind wrapper
                    // rescues the staged feeds and queued jobs and
                    // posts the death report — the same path a
                    // spontaneous panic takes. The reply is deferred:
                    // the supervisor harvests the report (or gives up
                    // at the deadline) and `finish_kill` answers once
                    // every recovery adopt has resolved.
                    //
                    // The Die job is enqueued non-blocking: the drill's
                    // target is by definition a suspect worker, and a
                    // wedged worker with a full queue must not freeze
                    // the router (and every client behind it) on a
                    // blocking send. A full queue bounces the drill
                    // with `backpressure` — no KillState is left
                    // behind, so the caller can simply retry.
                    r.shards[shard].depth.fetch_add(1, Ordering::Relaxed);
                    match r.shards[shard].tx.try_send(Job::Die) {
                        Ok(()) => {
                            r.kills.insert(
                                shard,
                                KillState {
                                    reply,
                                    deadline: Instant::now() + KILL_REPORT_WAIT,
                                    pending: None,
                                    recovered: 0,
                                },
                            );
                        }
                        Err(mpsc::TrySendError::Full(_)) => {
                            r.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
                            let _ = reply.send(backpressure_json(
                                &format!("shard {shard} queue full, kill not delivered"),
                                r.overload.retry_after_ms,
                            ));
                        }
                        Err(mpsc::TrySendError::Disconnected(_)) => {
                            // Died on its own in the meantime: run the
                            // usual death handling; nothing to drill.
                            r.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
                            r.handle_death(shard);
                            let _ = reply.send(obj(&[
                                ("killed", Json::Num(shard as f64)),
                                ("recovered", Json::Num(0.0)),
                            ]));
                        }
                    }
                }
            }
            RouterMsg::PoolAdd { reply } => r.add_worker(&reply),
            RouterMsg::PoolDrain { shard, reply } => {
                if shard >= r.shards.len() {
                    let _ = reply.send(err_json(
                        ErrCode::BadRequest,
                        &format!("no such shard {shard}"),
                    ));
                } else if r.life[shard] != ShardLifecycle::Active {
                    let _ = reply.send(err_json(
                        ErrCode::BadRequest,
                        &format!(
                            "shard {shard} is {} and cannot drain",
                            r.life[shard].as_str()
                        ),
                    ));
                } else if r
                    .life
                    .iter()
                    .filter(|&&l| l == ShardLifecycle::Active)
                    .count()
                    < 2
                {
                    let _ = reply.send(err_json(
                        ErrCode::BadRequest,
                        "cannot drain the last active worker",
                    ));
                } else {
                    r.life[shard] = ShardLifecycle::Draining;
                    r.drains.insert(
                        shard,
                        DrainState {
                            deadline: Instant::now() + r.drain_deadline,
                            reply,
                            migrated: 0,
                        },
                    );
                    // Start the first evict batch immediately; the
                    // supervisor tick pipelines the rest.
                    r.advance_drain(shard);
                }
            }
            RouterMsg::PoolStatus { reply } => {
                let _ = reply.send(r.pool_status_json());
            }
            RouterMsg::Shutdown => break,
        }
    }
    // Stop every worker (explicit shutdown, or every client handle
    // gone); workers flush their staged batches before exiting. Routed
    // through `send` so queue-depth accounting stays balanced.
    for i in 0..r.shards.len() {
        if r.is_live(i) {
            r.send(i, Job::Shutdown);
        }
    }
}

/// What shard 0 hands back to [`ShardPool::start`] once the engine is
/// built: the policy, the worker seeds, and its own channel/cache set.
struct Init {
    shard_cfg: ShardConfig,
    overload: OverloadPolicy,
    seeds: Vec<WorkerSeed>,
    /// Seed template for runtime `pool add` scale-up — minted only when
    /// the config's worker ceiling leaves room to grow and the backend
    /// supports cloning.
    template: Option<WorkerSeed>,
    tx0: mpsc::SyncSender<Job>,
    depth0: Arc<AtomicUsize>,
    cache0: Arc<Mutex<ShardSnapshot>>,
    live0: Arc<WorkerLiveness>,
}

/// A finished session's transcript and serving metrics, as reported by
/// [`ShardPool::finish`].
#[derive(Debug, Clone)]
pub struct Finished {
    /// The decoded transcript.
    pub text: String,
    /// Total hypothesis score (acoustic + LM + penalties).
    pub score: f64,
    /// Real-time factor over the session's compute.
    pub rtf: f64,
    /// Decoding steps executed.
    pub steps: usize,
    /// Mean lanes per fused step this session shared.
    pub batch_occupancy: f64,
    /// Steps decoded at a reduced-quality degrade rung (0 = the whole
    /// session ran at full quality).
    pub degraded_steps: usize,
    /// Degrade-rung changes observed while this session decoded.
    pub degrade_transitions: usize,
}

/// One exact N-best hypothesis, as reported by [`ShardPool::nbest`].
#[derive(Debug, Clone)]
pub struct NbestHyp {
    /// The hypothesis text.
    pub text: String,
    /// Exact first-pass score (acoustic + LM + penalties).
    pub score: f64,
    /// Second-pass score when the engine carries a rescoring LM;
    /// equals `score` otherwise.
    pub rescore: f64,
}

/// A finished session's transcript plus its exact N-best list, as
/// reported by [`ShardPool::nbest`].
#[derive(Debug, Clone)]
pub struct NbestFinished {
    /// The 1-best transcript — bit-identical to [`ShardPool::finish`].
    pub text: String,
    /// The 1-best total score.
    pub score: f64,
    /// The exact N-best list, best first.
    pub hyps: Vec<NbestHyp>,
}

/// A live session's progress, as reported by [`ShardPool::resume`] —
/// what a reconnecting client needs to continue exactly where the
/// server's acknowledged state left off.
#[derive(Debug, Clone)]
pub struct Resumed {
    /// Decoding steps the server has executed for this session.
    pub steps: usize,
    /// Acoustic frames consumed by the decoder.
    pub frames: usize,
    /// Samples fed but not yet consumed by a step (held server-side;
    /// the client must not re-send them).
    pub buffered_samples: usize,
    /// Current best partial transcript.
    pub partial: String,
}

/// In-process handle to a sharded serving stack: a router thread over
/// `ShardConfig::workers` device workers, each owning its shard of
/// sessions over the shared model. The TCP [`super::Server`] is a thin
/// protocol front-end over this; tests and examples drive it directly
/// (no sockets, no JSON float round-trips — the cross-shard parity
/// suite needs bit-exact audio in and scores out).
///
/// Cloning the pool clones the client handle, not the workers; any
/// clone may issue requests concurrently.
#[derive(Clone)]
pub struct ShardPool {
    tx: mpsc::SyncSender<RouterMsg>,
    workers: usize,
    retry_after_ms: u64,
}

impl ShardPool {
    /// Build the engine on shard 0's thread (PJRT handles are not
    /// `Send`), seed `engine.shard_cfg.workers - 1` further workers from
    /// it, and start the router. Blocks until the engine is built so
    /// construction errors surface here, exactly like `Server::start`.
    pub fn start(
        make_engine: impl FnOnce() -> Result<Engine> + Send + 'static,
        queue_depth: usize,
    ) -> Result<ShardPool> {
        let (router_tx, router_rx) = mpsc::sync_channel::<RouterMsg>(queue_depth);
        let (retire_tx, retire_rx) = mpsc::channel::<u64>();
        let (ckpt_tx, ckpt_rx) = mpsc::channel::<(u64, u64, Vec<u8>)>();
        let (migr_tx, migr_rx) = mpsc::channel::<MigrEvent>();
        let (init_tx, init_rx) = mpsc::channel::<Result<Init, String>>();
        let shard0_retire = retire_tx.clone();
        let shard0_ckpt = ckpt_tx.clone();
        let shard0_migr = migr_tx.clone();
        std::thread::Builder::new()
            .name("asrpu-shard-0".into())
            .spawn(move || {
                let engine = match make_engine() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = init_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                let shard_cfg = engine.shard_cfg.clone();
                let mut seeds = Vec::new();
                for _ in 1..shard_cfg.workers {
                    match engine.clone_worker() {
                        Some(seed) => seeds.push(seed),
                        // The builder rejects this combination; defend
                        // against hand-assembled engines anyway.
                        None => {
                            let _ = init_tx.send(Err(format!(
                                "backend '{}' cannot serve {} workers",
                                engine.backend().name(),
                                shard_cfg.workers
                            )));
                            return;
                        }
                    }
                }
                // The elastic-scale-up template: one extra seed, minted
                // only when the ceiling leaves room to grow (cloning
                // costs a model handle) and the backend supports it.
                let template = if shard_cfg.effective_max_workers() > shard_cfg.workers {
                    engine.clone_worker()
                } else {
                    None
                };
                let (tx0, rx0) = mpsc::sync_channel::<Job>(queue_depth);
                let depth0 = Arc::new(AtomicUsize::new(0));
                let cache0 = Arc::new(Mutex::new(ShardSnapshot::empty(0)));
                let live0 = Arc::new(WorkerLiveness::new());
                let _ = init_tx.send(Ok(Init {
                    shard_cfg,
                    overload: engine.overload.clone(),
                    seeds,
                    template,
                    tx0: tx0.clone(),
                    depth0: Arc::clone(&depth0),
                    cache0: Arc::clone(&cache0),
                    live0: Arc::clone(&live0),
                }));
                drop(tx0);
                let worker =
                    Worker::new(0, engine, depth0, shard0_retire, shard0_ckpt, shard0_migr, cache0);
                run_worker(worker, rx0, live0);
            })
            .context("spawning shard 0")?;
        let init = match init_rx.recv() {
            Ok(Ok(init)) => init,
            Ok(Err(msg)) => anyhow::bail!("engine init failed: {msg}"),
            Err(_) => anyhow::bail!("engine init thread died"),
        };
        let mut handles = vec![ShardHandle {
            tx: init.tx0,
            depth: init.depth0,
            cache: init.cache0,
            generation: Arc::new(AtomicU64::new(0)),
            liveness: init.live0,
        }];
        for (i, seed) in init.seeds.into_iter().enumerate() {
            let shard = i + 1;
            let (tx, rx) = mpsc::sync_channel::<Job>(queue_depth);
            let depth = Arc::new(AtomicUsize::new(0));
            let cache = Arc::new(Mutex::new(ShardSnapshot::empty(shard)));
            let liveness = Arc::new(WorkerLiveness::new());
            let worker_depth = Arc::clone(&depth);
            let worker_cache = Arc::clone(&cache);
            let worker_live = Arc::clone(&liveness);
            let worker_retire = retire_tx.clone();
            let worker_ckpt = ckpt_tx.clone();
            let worker_migr = migr_tx.clone();
            std::thread::Builder::new()
                .name(format!("asrpu-shard-{shard}"))
                .spawn(move || {
                    let worker = Worker::new(
                        shard,
                        seed.into_engine(),
                        worker_depth,
                        worker_retire,
                        worker_ckpt,
                        worker_migr,
                        worker_cache,
                    );
                    run_worker(worker, rx, worker_live)
                })
                .with_context(|| format!("spawning shard {shard}"))?;
            handles.push(ShardHandle {
                tx,
                depth,
                cache,
                generation: Arc::new(AtomicU64::new(0)),
                liveness,
            });
        }
        let workers = handles.len();
        let retry_after_ms = init.overload.retry_after_ms;
        let router = Router {
            shards: handles,
            life: vec![ShardLifecycle::Active; workers],
            rejected: vec![0; workers],
            assign: HashMap::new(),
            open_count: vec![0; workers],
            next_id: 1,
            rebalance_threshold: init.shard_cfg.rebalance_threshold,
            checkpoint_interval: init.shard_cfg.checkpoint_interval,
            overload: init.overload,
            shed_pending: Vec::new(),
            shed: 0,
            shed_ids: BTreeSet::new(),
            shed_evicted: 0,
            admission_rejected: 0,
            panics_detected: 0,
            checkpoints: HashMap::new(),
            recovered: 0,
            retire_rx,
            ckpt_rx,
            migr_rx,
            template: init.template,
            // The router retains the back-channel senders so it can
            // mint them into runtime-added workers; the channels die
            // with the router, which outlives every worker.
            retire_tx,
            ckpt_tx,
            migr_tx,
            queue_depth,
            max_workers: init.shard_cfg.effective_max_workers(),
            drain_deadline: Duration::from_millis(init.shard_cfg.drain_deadline_ms),
            drains: HashMap::new(),
            kills: HashMap::new(),
            legs: HashMap::new(),
            next_token: 1,
            parked: HashMap::new(),
            pending_adopts: Vec::new(),
            deferred: VecDeque::new(),
            deferred_count: HashMap::new(),
        };
        std::thread::Builder::new()
            .name("asrpu-router".into())
            .spawn(move || router_loop(router_rx, router))
            .context("spawning router")?;
        Ok(ShardPool { tx: router_tx, workers, retry_after_ms })
    }

    /// Number of device workers the pool *started* with. The live
    /// count changes at runtime via [`Self::add_worker`] and
    /// [`Self::drain_worker`]; see [`Self::pool_status`].
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Add a worker at runtime, seeded from the startup engine
    /// template. Returns the new shard's index. Errors when the pool
    /// is already at `max_workers` live workers, or when the engine's
    /// backend cannot clone workers (elasticity requires it).
    pub fn add_worker(&self) -> Result<usize> {
        let r = self.call(|reply| RouterMsg::PoolAdd { reply })?;
        r.get("shard")
            .and_then(Json::as_usize)
            .context("malformed pool add reply")
    }

    /// Drain a worker at runtime: it stops taking new sessions, its
    /// live sessions pipeline-migrate onto the remaining active workers
    /// (bit-identically — state travels as snapshots), and the worker
    /// retires once empty. Blocks until the drain completes (returns
    /// the number of sessions migrated off) or its deadline aborts it.
    pub fn drain_worker(&self, shard: usize) -> Result<usize> {
        let r = self.call(|reply| RouterMsg::PoolDrain { shard, reply })?;
        r.get("migrated")
            .and_then(Json::as_usize)
            .context("malformed pool drain reply")
    }

    /// The pool's current shape: live/max worker counts, in-progress
    /// drains, and per-shard lifecycle, session count and queue depth.
    pub fn pool_status(&self) -> Result<Json> {
        self.call(|reply| RouterMsg::PoolStatus { reply })
    }

    /// The overload policy's client backoff hint, for front-ends that
    /// bounce work before it ever reaches the router — the TCP conn
    /// threads' queue-full answer carries the same hint as the policy
    /// bounces the router itself issues.
    pub(crate) fn retry_after_ms(&self) -> u64 {
        self.retry_after_ms
    }

    /// A request sender for front-ends that manage their own replies
    /// (the TCP connection threads).
    pub(crate) fn sender(&self) -> mpsc::SyncSender<RouterMsg> {
        self.tx.clone()
    }

    fn call(&self, make: impl FnOnce(mpsc::Sender<Json>) -> RouterMsg) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(make(tx))
            .map_err(|_| anyhow::anyhow!("pool is shut down"))?;
        let resp = rx.recv().context("router dropped request")?;
        Self::ok_or_err(resp)
    }

    /// Turn a protocol error payload into an `Err` carrying its code.
    fn ok_or_err(resp: Json) -> Result<Json> {
        if let Some(e) = resp.get("error") {
            let code = e.get("code").and_then(Json::as_str).unwrap_or("internal");
            let msg = e.get("message").and_then(Json::as_str).unwrap_or("");
            anyhow::bail!("{code}: {msg}");
        }
        Ok(resp)
    }

    /// Open a session; returns its globally unique id.
    pub fn open(&self) -> Result<u64> {
        let r = self.call(|reply| RouterMsg::Open { reply })?;
        r.get("session")
            .and_then(Json::as_f64)
            .map(|v| v as u64)
            .context("malformed open reply")
    }

    /// Feed audio, blocking until the session's batch flushes; returns
    /// the steps run since staging and the current partial transcript.
    pub fn feed(&self, session: u64, samples: &[f32]) -> Result<(usize, String)> {
        let rx = self.feed_async(session, samples)?;
        let resp = rx.recv().context("router dropped feed")?;
        Self::parse_feed(resp)
    }

    /// Stage a feed without blocking: the receiver yields the reply when
    /// the session's batch flushes (interpret it with
    /// [`Self::parse_feed`]). Fan-out callers stage one feed per session
    /// and then collect, letting the device fuse them into one batch.
    pub fn feed_async(&self, session: u64, samples: &[f32]) -> Result<mpsc::Receiver<Json>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Feed {
                session,
                samples: samples.to_vec(),
                enqueued: Instant::now(),
                reply: tx,
            })
            .map_err(|_| anyhow::anyhow!("pool is shut down"))?;
        Ok(rx)
    }

    /// Interpret a feed reply from [`Self::feed_async`].
    pub fn parse_feed(resp: Json) -> Result<(usize, String)> {
        let r = Self::ok_or_err(resp)?;
        let steps = r
            .get("steps")
            .and_then(Json::as_usize)
            .context("malformed feed reply")?;
        let partial = r
            .get("partial")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string();
        Ok((steps, partial))
    }

    /// Finish a session: flush remaining audio and return the final
    /// transcript + metrics.
    pub fn finish(&self, session: u64) -> Result<Finished> {
        let r = self.call(|reply| RouterMsg::Finish { session, reply })?;
        Ok(Finished {
            text: r
                .get("text")
                .and_then(Json::as_str)
                .context("malformed finish reply")?
                .to_string(),
            score: r.get("score").and_then(Json::as_f64).unwrap_or(0.0),
            rtf: r.get("rtf").and_then(Json::as_f64).unwrap_or(0.0),
            steps: r.get("steps").and_then(Json::as_usize).unwrap_or(0),
            batch_occupancy: r.get("batch_occupancy").and_then(Json::as_f64).unwrap_or(0.0),
            degraded_steps: r.get("degraded_steps").and_then(Json::as_usize).unwrap_or(0),
            degrade_transitions: r
                .get("degrade_transitions")
                .and_then(Json::as_usize)
                .unwrap_or(0),
        })
    }

    /// Finish a session through its lattice: the exact 1-best
    /// transcript (bit-identical to [`ShardPool::finish`]) plus the
    /// N-best list, rescored when the engine carries a second-pass LM.
    /// Errors with `bad_request` on engines built without N-best
    /// ([`crate::coordinator::EngineBuilder::nbest`]); the session then
    /// stays open and can still `finish`.
    pub fn nbest(&self, session: u64) -> Result<NbestFinished> {
        let r = self.call(|reply| RouterMsg::Nbest { session, reply })?;
        let hyps = match r.get("nbest") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(|h| NbestHyp {
                    text: h
                        .get("text")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    score: h.get("score").and_then(Json::as_f64).unwrap_or(0.0),
                    rescore: h.get("rescore").and_then(Json::as_f64).unwrap_or(0.0),
                })
                .collect(),
            _ => Vec::new(),
        };
        Ok(NbestFinished {
            text: r
                .get("text")
                .and_then(Json::as_str)
                .context("malformed nbest reply")?
                .to_string(),
            score: r.get("score").and_then(Json::as_f64).unwrap_or(0.0),
            hyps,
        })
    }

    /// Re-attach to a session (the protocol's `resume` op): report how
    /// far the server has decoded so a reconnecting client replays only
    /// unacknowledged audio. If the session's shard died, recovery runs
    /// first and the report reflects the restored checkpoint — the
    /// client's continuation point.
    pub fn resume(&self, session: u64) -> Result<Resumed> {
        let r = self.call(|reply| RouterMsg::Resume { session, reply })?;
        Ok(Resumed {
            steps: r
                .get("steps")
                .and_then(Json::as_usize)
                .context("malformed resume reply")?,
            frames: r.get("frames").and_then(Json::as_usize).unwrap_or(0),
            buffered_samples: r
                .get("buffered_samples")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            partial: r
                .get("partial")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
        })
    }

    /// Kill one worker *without* letting it flush or checkpoint — the
    /// dead-shard crash hook behind the recovery tests and fault
    /// drills. Blocks until the worker is provably gone, its sessions
    /// have been re-adopted from their checkpoints, and the feeds it
    /// was holding staged (accepted, never acknowledged) have been
    /// replayed on the recovery shards — those clients' pending
    /// requests answer normally rather than bouncing. Returns how many
    /// sessions recovery restored.
    pub fn kill_worker(&self, shard: usize) -> Result<usize> {
        let r = self.call(|reply| RouterMsg::Kill { shard, reply })?;
        Ok(r.get("recovered").and_then(Json::as_usize).unwrap_or(0))
    }

    /// Aggregated per-shard serving metrics (the `stats` op's payload).
    /// Served from worker-published caches — never waits on a worker.
    pub fn stats(&self) -> Result<Json> {
        self.call(|reply| RouterMsg::Stats { reply })
    }

    /// Device/config introspection (the `config` op's payload).
    pub fn config(&self) -> Result<Json> {
        self.call(|reply| RouterMsg::Config { reply })
    }

    /// Stop the router and every worker (idempotent). Uses a blocking
    /// send so the request survives a momentarily full queue — the
    /// router always drains, so the wait is bounded by one queue's
    /// in-flight work; a router that already exited is a no-op.
    pub fn shutdown(&self) {
        let _ = self.tx.send(RouterMsg::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::TdsModel;
    use crate::config::{BatchConfig, ModelConfig};
    use crate::synth::Synthesizer;
    use crate::util::rng::Rng;

    fn pool(workers: usize, threshold: usize) -> ShardPool {
        ShardPool::start(
            move || {
                Ok(Engine::builder()
                    .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                    .batch(BatchConfig::default())
                    .shards(crate::config::ShardConfig {
                        workers,
                        rebalance_threshold: threshold,
                        checkpoint_interval: 1,
                        ..Default::default()
                    })
                    .build()?)
            },
            64,
        )
        .unwrap()
    }

    fn reference_engine() -> Engine {
        Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
            .build()
            .unwrap()
    }

    fn utterance(seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        Synthesizer::default().render(&[1, 4], &mut rng).samples
    }

    fn sum_over_shards(stats: &Json, key: &str) -> f64 {
        stats
            .get("shards")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.get(key).unwrap().as_f64().unwrap())
            .sum()
    }

    #[test]
    fn single_worker_pool_round_trip() {
        let p = pool(1, 2);
        assert_eq!(p.workers(), 1);
        let id = p.open().unwrap();
        let audio = utterance(3);
        let (steps, _partial) = p.feed(id, &audio).unwrap();
        assert!(steps > 0);
        let done = p.finish(id).unwrap();
        assert!(!done.text.is_empty() || done.steps > 0);
        let stats = p.stats().unwrap();
        assert_eq!(stats.get("workers").unwrap().as_f64(), Some(1.0));
        assert!(p.finish(id).is_err(), "finished session must be unknown");
        p.shutdown();
    }

    #[test]
    fn rebalance_migrates_queued_sessions_deterministically() {
        // Deterministic assignment (least-open, lowest index on ties):
        // sessions 1,3 land on shard 0 and 2,4 on shard 1. Finishing 1
        // and 3 empties shard 0 → imbalance 2 hits the threshold and the
        // router migrates the lowest eligible id (2) to shard 0.
        let p = pool(2, 2);
        let ids: Vec<u64> = (0..4).map(|_| p.open().unwrap()).collect();
        assert_eq!(ids, vec![1, 2, 3, 4]);
        p.finish(1).unwrap();
        p.finish(3).unwrap();
        let stats = p.stats().unwrap();
        assert_eq!(
            sum_over_shards(&stats, "adopted"),
            1.0,
            "exactly one session migrates: {stats:?}"
        );
        assert_eq!(stats.get("imbalance").unwrap().as_f64(), Some(0.0));
        // The migrated session still decodes exactly like a 1-worker
        // engine fed the same audio.
        let reference = reference_engine();
        for id in [2u64, 4] {
            let audio = utterance(10 + id);
            let (t_ref, _) = reference.decode_utterance(&audio).unwrap();
            p.feed(id, &audio).unwrap();
            let done = p.finish(id).unwrap();
            assert_eq!(done.text, t_ref.text, "session {id}");
            assert_eq!(done.score, t_ref.score as f64, "session {id}");
        }
        p.shutdown();
    }

    #[test]
    fn started_sessions_migrate_live_and_stay_bit_identical() {
        // The tentpole invariant at the pool level: a session that has
        // already run decoding steps migrates between shards
        // (evict → snapshot → adopt → restore) and its final transcript
        // is bit-identical to an unmigrated decode.
        let p = pool(2, 2);
        let a = p.open().unwrap(); // shard 0
        let b = p.open().unwrap(); // shard 1
        let c = p.open().unwrap(); // shard 0
        let audio: HashMap<u64, Vec<f32>> =
            [a, b, c].iter().map(|&id| (id, utterance(20 + id))).collect();
        // Run steps on every session so all are mid-utterance.
        for &id in &[a, b, c] {
            let half = audio[&id].len() / 2;
            let (steps, _) = p.feed(id, &audio[&id][..half]).unwrap();
            assert!(steps > 0, "session {id} must have started");
        }
        // Finishing b empties shard 1 → imbalance 2: the lowest-id
        // shard-0 session (a) migrates live to shard 1.
        p.finish(b).unwrap();
        let stats = p.stats().unwrap();
        assert_eq!(
            sum_over_shards(&stats, "adopted"),
            1.0,
            "one live session must migrate: {stats:?}"
        );
        assert_eq!(
            sum_over_shards(&stats, "migrated"),
            1.0,
            "the evicting shard must report the hand-off: {stats:?}"
        );
        assert!(
            sum_over_shards(&stats, "checkpoints") >= 3.0,
            "every flushed session checkpoints: {stats:?}"
        );
        let reference = reference_engine();
        for id in [a, c] {
            let half = audio[&id].len() / 2;
            let (t_ref, _) = reference.decode_utterance(&audio[&id]).unwrap();
            p.feed(id, &audio[&id][half..]).unwrap();
            let done = p.finish(id).unwrap();
            assert_eq!(done.text, t_ref.text, "session {id}");
            assert_eq!(done.score, t_ref.score as f64, "session {id}");
        }
        p.shutdown();
    }

    #[test]
    fn killed_worker_sessions_recover_from_checkpoints() {
        // Crash one worker mid-stream: its sessions re-adopt onto the
        // survivor from their checkpoints, the in-flight client keeps
        // going, and transcripts stay bit-identical (every feed was
        // flushed, so checkpoints cover all acknowledged audio).
        let p = pool(2, 0); // rebalancing off: placement stays put
        let a = p.open().unwrap(); // shard 0
        let b = p.open().unwrap(); // shard 1
        let audio_a = utterance(70);
        let audio_b = utterance(71);
        let half_a = audio_a.len() / 2;
        let half_b = audio_b.len() / 2;
        p.feed(a, &audio_a[..half_a]).unwrap();
        p.feed(b, &audio_b[..half_b]).unwrap();
        let recovered = p.kill_worker(0).unwrap();
        assert_eq!(recovered, 1, "shard 0's one session must recover");
        // Resume reports the restored progress a reconnecting client
        // would continue from.
        let res = p.resume(a).unwrap();
        assert!(res.steps > 0, "recovered session kept its steps");
        p.feed(a, &audio_a[half_a..]).unwrap();
        p.feed(b, &audio_b[half_b..]).unwrap();
        let reference = reference_engine();
        let (t_a, _) = reference.decode_utterance(&audio_a).unwrap();
        let (t_b, _) = reference.decode_utterance(&audio_b).unwrap();
        let done_a = p.finish(a).unwrap();
        assert_eq!(done_a.text, t_a.text, "recovered session transcript");
        assert_eq!(done_a.score, t_a.score as f64);
        let done_b = p.finish(b).unwrap();
        assert_eq!(done_b.text, t_b.text, "surviving shard unaffected");
        let stats = p.stats().unwrap();
        assert_eq!(stats.get("workers").unwrap().as_f64(), Some(2.0));
        assert_eq!(stats.get("responding").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("recovered").unwrap().as_f64(), Some(1.0));
        // Killing an already-dead shard is a harmless no-op.
        assert_eq!(p.kill_worker(0).unwrap(), 0);
        p.shutdown();
    }

    #[test]
    fn kill_worker_replays_in_flight_feeds_without_a_bounce() {
        // A feed staged (accepted, not yet acknowledged) on a worker at
        // the moment it is killed must not bounce with
        // `internal`/`unknown_session`: the Die ack hands the staged
        // feeds back to the router, which replays them on the sessions'
        // recovery shards. Staged audio always postdates the covering
        // checkpoint, so the replay repeats no audio and the final
        // transcript stays bit-identical.
        let p = ShardPool::start(
            move || {
                Ok(Engine::builder()
                    .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                    // A huge wait budget keeps a partial batch staged
                    // until the kill lands (no timer-driven flush).
                    .batch(BatchConfig { max_batch: 8, max_wait_frames: 100_000 })
                    .shards(crate::config::ShardConfig {
                        workers: 2,
                        rebalance_threshold: 0,
                        checkpoint_interval: 1,
                        ..Default::default()
                    })
                    .build()?)
            },
            64,
        )
        .unwrap();
        let a = p.open().unwrap(); // shard 0
        let _b = p.open().unwrap(); // shard 1
        let c = p.open().unwrap(); // shard 0
        let audio = utterance(80);
        let half = audio.len() / 2;
        // Feeds covering both of shard 0's sessions flush (every open
        // session staged) — and checkpoint, covering all acked audio.
        let rx_a = p.feed_async(a, &audio[..half]).unwrap();
        let rx_c = p.feed_async(c, &utterance(81)).unwrap();
        ShardPool::parse_feed(rx_a.recv().unwrap()).unwrap();
        ShardPool::parse_feed(rx_c.recv().unwrap()).unwrap();
        // This feed stays staged: one staged session < two open ones,
        // and the wait budget never expires.
        let rx2 = p.feed_async(a, &audio[half..]).unwrap();
        // The kill is queued behind the feed on both the router and the
        // shard-0 job queue (FIFO), so the worker stages the feed and
        // then dies holding it.
        assert_eq!(p.kill_worker(0).unwrap(), 2, "both sessions recover");
        // Finishing forces the recovery shard to flush its staged work
        // (the replayed feed) before extracting the transcript.
        let done = p.finish(a).unwrap();
        let replayed = ShardPool::parse_feed(rx2.recv().unwrap());
        assert!(replayed.is_ok(), "replayed feed bounced: {replayed:?}");
        let reference = reference_engine();
        let (t_ref, _) = reference.decode_utterance(&audio).unwrap();
        assert_eq!(done.text, t_ref.text, "replayed audio decodes bit-identically");
        assert_eq!(done.score, t_ref.score as f64);
        p.shutdown();
    }

    #[test]
    fn kill_worker_bounces_instead_of_blocking_on_a_wedged_queue() {
        // Regression (KNOWN_FAILURES residual): the kill drill used a
        // *blocking* send for the Die job, so killing a wedged worker
        // whose 1-slot queue was already full froze the router — and
        // with it every other client — until the worker drained. The
        // drill must bounce with `backpressure` instead, leave no
        // half-armed KillState behind, and succeed on a later retry.
        let p = ShardPool::start(
            move || {
                Ok(Engine::builder()
                    .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                    .batch(BatchConfig::default())
                    // The wedge: the worker sleeps before answering each
                    // flushed feed, so a second feed parks in its single
                    // queue slot for the whole window.
                    .fault_reply_delay_ms(1500)
                    .shards(crate::config::ShardConfig {
                        workers: 2,
                        rebalance_threshold: 0,
                        checkpoint_interval: 1,
                        ..Default::default()
                    })
                    .build()?)
            },
            1, // queue depth 1: one in-flight job wedges the shard
        )
        .unwrap();
        let a = p.open().unwrap(); // shard 0
        let audio = utterance(90);
        let half = audio.len() / 2;
        // Feed 1 occupies the worker (it sleeps inside the drain);
        // feed 2 then fills the queue slot behind it.
        let rx1 = p.feed_async(a, &audio[..half]).unwrap();
        // Let the worker pop feed 1 (and start its sleepy drain) so
        // feed 2 lands in the queue slot instead of bouncing.
        std::thread::sleep(std::time::Duration::from_millis(200));
        let rx2 = p.feed_async(a, &audio[half..]).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(200));
        // The drill must answer promptly with a structured bounce, not
        // block the router behind the wedged worker.
        let t0 = Instant::now();
        let err = p.kill_worker(0).expect_err("full queue must bounce the drill");
        assert!(t0.elapsed() < Duration::from_millis(700), "kill blocked the router");
        let msg = format!("{err:#}");
        assert!(msg.contains("backpressure"), "{msg}");
        // The bounced drill armed nothing: both wedged feeds answer
        // normally once the worker drains.
        ShardPool::parse_feed(rx1.recv().unwrap()).unwrap();
        ShardPool::parse_feed(rx2.recv().unwrap()).unwrap();
        // Retrying against the drained queue completes the drill and
        // recovers the session from its checkpoints.
        assert_eq!(p.kill_worker(0).unwrap(), 1, "retry must recover the session");
        let done = p.finish(a).unwrap();
        let (t_ref, _) = reference_engine().decode_utterance(&audio).unwrap();
        assert_eq!(done.text, t_ref.text, "recovered transcript");
        p.shutdown();
    }

    #[test]
    fn resume_reports_progress_and_unknowns() {
        let p = pool(1, 2);
        let id = p.open().unwrap();
        let res = p.resume(id).unwrap();
        assert_eq!(res.steps, 0);
        assert_eq!(res.buffered_samples, 0);
        let audio = utterance(5);
        let (steps, _) = p.feed(id, &audio).unwrap();
        let res = p.resume(id).unwrap();
        assert_eq!(res.steps, steps);
        assert!(res.buffered_samples < 1520, "whole steps were consumed");
        assert_eq!(res.frames, steps * 4, "4 score vectors per step");
        let err = format!("{:#}", p.resume(999).unwrap_err());
        assert!(err.contains("unknown_session"), "{err}");
        p.finish(id).unwrap();
        assert!(p.resume(id).is_err(), "finished session is gone");
        p.shutdown();
    }

    #[test]
    fn multi_worker_pool_reports_per_shard_stats() {
        let p = pool(4, 0);
        let ids: Vec<u64> = (0..8).map(|_| p.open().unwrap()).collect();
        for &id in &ids {
            p.feed(id, &utterance(40 + id)).unwrap();
        }
        let stats = p.stats().unwrap();
        assert_eq!(stats.get("workers").unwrap().as_f64(), Some(4.0));
        assert_eq!(stats.get("responding").unwrap().as_f64(), Some(4.0));
        let shards = stats.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 4);
        // Deterministic least-loaded assignment: 2 sessions per shard.
        for s in shards {
            assert_eq!(s.get("sessions").unwrap().as_f64(), Some(2.0), "{stats:?}");
        }
        for &id in &ids {
            p.finish(id).unwrap();
        }
        p.shutdown();
    }

    /// A pool with an overload policy and optional fault hooks —
    /// `panic_after`/`reply_delay` of 0 leave the hook off.
    fn overload_pool(
        workers: usize,
        queue: usize,
        overload: crate::config::OverloadPolicy,
        panic_after: u64,
        reply_delay: u64,
    ) -> ShardPool {
        ShardPool::start(
            move || {
                let mut b = Engine::builder()
                    .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                    .batch(BatchConfig::default())
                    .shards(crate::config::ShardConfig {
                        workers,
                        rebalance_threshold: 0,
                        checkpoint_interval: 1,
                        ..Default::default()
                    })
                    .overload(overload.clone());
                if panic_after > 0 {
                    b = b.fault_panic_after_steps(panic_after);
                }
                if reply_delay > 0 {
                    b = b.fault_reply_delay_ms(reply_delay);
                }
                Ok(b.build()?)
            },
            queue,
        )
        .unwrap()
    }

    /// Open via the raw router channel, returning the unparsed reply —
    /// the only way a test can see a rejection's `retry_after_ms`.
    fn raw_open(p: &ShardPool) -> Json {
        let (tx, rx) = mpsc::channel();
        p.sender().send(RouterMsg::Open { reply: tx }).unwrap();
        rx.recv().unwrap()
    }

    #[test]
    fn admission_limit_rejects_opens_with_retry_hint() {
        let p = overload_pool(
            1,
            64,
            crate::config::OverloadPolicy {
                admit_sessions_per_shard: 1,
                retry_after_ms: 75,
                ..Default::default()
            },
            0,
            0,
        );
        let a = p.open().unwrap();
        // Over the limit: a structured backpressure rejection carrying
        // the policy's retry hint, not a hang and not a plain error.
        let resp = raw_open(&p);
        let e = resp.get("error").expect("over-limit open must be rejected");
        assert_eq!(e.get("code").and_then(Json::as_str), Some("backpressure"));
        assert_eq!(e.get("retry_after_ms").and_then(Json::as_f64), Some(75.0));
        let err = format!("{:#}", p.open().unwrap_err());
        assert!(err.contains("backpressure"), "{err}");
        let stats = p.stats().unwrap();
        assert_eq!(stats.get("rejected_admission").unwrap().as_f64(), Some(2.0));
        // Admission recovers the moment a session closes.
        p.finish(a).unwrap();
        let b = p.open().unwrap();
        p.finish(b).unwrap();
        p.shutdown();
    }

    #[test]
    fn saturated_shard_sheds_never_started_sessions() {
        // Queue depth 1 plus a 400 ms reply delay wedges the single
        // worker inside one flush; jobs sent meanwhile saturate its
        // queue deterministically.
        let p = overload_pool(
            1,
            1,
            crate::config::OverloadPolicy {
                retry_after_ms: 30,
                shed_never_started: true,
                ..Default::default()
            },
            0,
            400,
        );
        let a = p.open().unwrap();
        let audio = utterance(60);
        // The lone open session stages and flushes immediately; the
        // reply-delay hook now holds the worker for 400 ms.
        let rx_a1 = p.feed_async(a, &audio).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        // B books onto the saturated shard (its Open occupies the one
        // queue slot) and never feeds.
        let (tx, rx_open) = mpsc::channel();
        p.sender().send(RouterMsg::Open { reply: tx }).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        // This feed finds the queue full: the policy sheds the oldest
        // never-started session (B) and bounces the feed with the hint.
        let rx_a2 = p.feed_async(a, &utterance(61)).unwrap();
        let bounce = rx_a2.recv().unwrap();
        let e = bounce.get("error").expect("feed into a full queue must bounce");
        assert_eq!(e.get("code").and_then(Json::as_str), Some("backpressure"));
        assert_eq!(e.get("retry_after_ms").and_then(Json::as_f64), Some(30.0));
        // The first feed still answers normally once the worker wakes,
        // and the worker-side open of B was processed (then shed).
        assert!(ShardPool::parse_feed(rx_a1.recv().unwrap()).unwrap().0 > 0);
        let b = rx_open.recv().unwrap().get("session").and_then(Json::as_f64).unwrap() as u64;
        // Router-side B is gone — and its owner learns *why*: the
        // dedicated session_shed code with its reopen hint, not the
        // indistinguishable unknown_session.
        let err = format!("{:#}", p.feed(b, &audio).unwrap_err());
        assert!(err.contains("session_shed"), "{err}");
        assert!(err.contains("reopen"), "{err}");
        let err = format!("{:#}", p.resume(b).unwrap_err());
        assert!(err.contains("session_shed"), "{err}");
        let stats = p.stats().unwrap();
        assert_eq!(stats.get("shed").unwrap().as_f64(), Some(1.0), "{stats:?}");
        // The shed notice reaches the worker once its queue drains.
        let mut worker_shed = 0.0;
        for _ in 0..100 {
            worker_shed = sum_over_shards(&p.stats().unwrap(), "shed");
            if worker_shed == 1.0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(worker_shed, 1.0);
        // The started session was never shed and finishes normally.
        p.finish(a).unwrap();
        p.shutdown();
    }

    #[test]
    fn supervisor_recovers_spontaneous_worker_panic_and_replays_feeds() {
        // Every worker engine panics at its third scoring attempt. Two
        // acked (and checkpointed) steps run on shard 0; the third feed
        // kills it mid-flush — *spontaneously*, with no Kill request in
        // flight. The supervisor must notice on its own, re-adopt the
        // session from its checkpoint and replay the staged feed so the
        // in-flight client never sees a bounce.
        let p = overload_pool(2, 64, crate::config::OverloadPolicy::default(), 2, 0);
        let a = p.open().unwrap(); // shard 0
        let b = p.open().unwrap(); // shard 1
        p.finish(b).unwrap(); // keep the survivor idle (fresh fault budget)
        let need = 1520; // samples_per_step(tiny_tds)
        let step = 1280; // step_len
        assert_eq!(p.feed(a, &vec![0.0; need]).unwrap().0, 1);
        assert_eq!(p.feed(a, &vec![0.0; step]).unwrap().0, 1);
        // Third step: the worker thread dies holding this feed staged.
        let rx = p.feed_async(a, &vec![0.0; step]).unwrap();
        let replayed = ShardPool::parse_feed(rx.recv().unwrap());
        assert!(replayed.is_ok(), "replayed feed bounced: {replayed:?}");
        assert_eq!(replayed.unwrap().0, 1, "exactly the lost step replays");
        let res = p.resume(a).unwrap();
        assert_eq!(res.steps, 3, "recovery restored both acked steps");
        let stats = p.stats().unwrap();
        assert_eq!(stats.get("responding").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("recovered").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("panics_detected").unwrap().as_f64(), Some(1.0));
        assert!(sum_over_shards(&stats, "heartbeats") >= 1.0, "{stats:?}");
        // The recovered transcript is bit-identical to an undisturbed
        // single-engine decode of the same audio.
        let reference = reference_engine();
        let (t_ref, _) = reference.decode_utterance(&vec![0.0; need + 2 * step]).unwrap();
        let done = p.finish(a).unwrap();
        assert_eq!(done.text, t_ref.text);
        assert_eq!(done.score, t_ref.score as f64);
        p.shutdown();
    }

    #[test]
    fn degrade_ladder_is_deterministic_and_restores_full_quality() {
        let base = crate::config::DecoderConfig::default();
        let overload = crate::config::OverloadPolicy {
            levels: vec![crate::config::DegradeLevel {
                enter_backlog_steps: 3,
                beam: base.beam / 2.0,
                max_hyps: (base.max_hyps / 2).max(1),
                max_batch: 1,
            }],
            ..Default::default()
        };
        let mut rng = Rng::new(90);
        let burst = Synthesizer::default().render(&[1, 4, 3, 6], &mut rng).samples;
        assert!(burst.len() >= 1520 + 2 * 1280, "burst must cross the 3-step threshold");
        let calm = utterance(91);
        let run = |overload: crate::config::OverloadPolicy| {
            let p = overload_pool(1, 64, overload, 0, 0);
            // One oversized feed: the whole backlog is ready at a single
            // flush, crossing the ladder's threshold.
            let id = p.open().unwrap();
            p.feed(id, &burst).unwrap();
            let stressed = p.finish(id).unwrap();
            // After the drain, a second session fed gently (≤ 2 ready
            // steps per flush) must see full quality.
            let id2 = p.open().unwrap();
            for chunk in calm.chunks(2560) {
                p.feed(id2, chunk).unwrap();
            }
            let calm_done = p.finish(id2).unwrap();
            let stats = p.stats().unwrap();
            p.shutdown();
            (stressed, calm_done, stats)
        };
        let (s1, c1, stats) = run(overload.clone());
        let (s2, c2, _) = run(overload);
        // Degradation engaged, was recorded per session, and is a
        // deterministic function of the admitted trace: two identical
        // runs agree bit for bit.
        assert!(s1.degraded_steps > 0, "{s1:?}");
        assert!(s1.degrade_transitions >= 1, "{s1:?}");
        assert_eq!(s1.text, s2.text);
        assert_eq!(s1.score, s2.score);
        assert_eq!(s1.degraded_steps, s2.degraded_steps);
        assert_eq!(s1.degrade_transitions, s2.degrade_transitions);
        assert!(sum_over_shards(&stats, "degraded_batches") >= 1.0, "{stats:?}");
        assert_eq!(
            sum_over_shards(&stats, "degrade_level"),
            0.0,
            "full quality restored after drain: {stats:?}"
        );
        // The gently-fed session never degraded and matches an engine
        // that has no overload policy at all, bit for bit.
        assert_eq!(c1.degraded_steps, 0, "{c1:?}");
        let reference = reference_engine();
        let mut s = reference.open(false).unwrap();
        for chunk in calm.chunks(2560) {
            reference.feed(&mut s, chunk).unwrap();
        }
        let t_ref = reference.finish(&mut s).unwrap();
        assert_eq!(c1.text, t_ref.text);
        assert_eq!(c1.score, t_ref.score as f64);
        assert_eq!(c1.text, c2.text);
        assert_eq!(c1.score, c2.score);
    }
}
