//! Sharded multi-worker serving: a deterministic session router over a
//! pool of device workers — the paper's pool-of-general-purpose-cores
//! thesis (§3) lifted to the serving layer. One coordinator no longer
//! funnels every session through a single device thread; instead
//! [`ShardPool`] spawns `ShardConfig::workers` shards, each owning its
//! own [`Batcher`], scratch arenas and acoustic-backend handle over the
//! *shared* model ([`Engine::clone_worker`] — weights behind an `Arc`),
//! and a router thread assigns sessions to shards.
//!
//! ## Determinism
//!
//! Transcripts are independent of the shard count: per-session decode
//! state never crosses lanes, `Engine::step_batch` is bit-identical to
//! scalar decoding for every lane (`tests/batch_parity.rs`), and every
//! worker serves the same weights — so any partition of a session set
//! across N identical workers yields exactly the 1-worker transcripts.
//! `tests/shard_parity.rs` enforces this end to end for N ∈ {2, 4} on
//! both native backends. *Initial* session→shard assignment is also
//! deterministic: the router picks the shard with the fewest open
//! sessions (lowest index on ties) using only router-side state.
//! Final placement under load is not — whether a rebalance migrates a
//! fed-but-unstarted session depends on wall-clock batch-flush timing
//! (a staged feed pins it) — but placement never affects transcripts,
//! which is the invariant that matters.
//!
//! ## Rebalancing
//!
//! Only *queued* sessions migrate — sessions that have not yet run a
//! decoding step, whose acoustic/decoder state is therefore still
//! pristine ([`Session::into_buffered`]). When the open-session imbalance
//! between the hottest and coldest shard reaches
//! `ShardConfig::rebalance_threshold`, the router evicts up to half the
//! difference from the hot shard and re-opens those sessions (buffered
//! audio intact) on the cold one. Started sessions are pinned to their
//! shard: their backend lane state is shard-resident and moving it
//! would break both `Send`-safety (PJRT) and the allocation story.
//!
//! ## Flow control
//!
//! Client-facing jobs are forwarded with a non-blocking `try_send`: a
//! shard whose queue is saturated bounces *its own* requests with
//! `backpressure` while the router keeps routing for every other shard
//! (head-of-line isolation). Router-internal transactions (snapshot
//! probes, evict/adopt migration legs, shutdown) use blocking sends —
//! they are serialized router work by design, and stats snapshots are
//! broadcast-then-collect so a stats poll stalls for the busiest single
//! worker, not the sum over shards.
//!
//! The TCP front-end ([`super::Server`]) is a thin protocol layer over
//! this module; tests and examples drive [`ShardPool`] directly — no
//! sockets, no JSON text round-trips, which is what lets the parity
//! suite demand *bit*-identical scores.
#![deny(missing_docs)]

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::config::ShardConfig;
use crate::util::json::Json;

use super::engine::{Batcher, Engine, Session, WorkerSeed};
use super::metrics::{ServeMetrics, ShardMetrics, ShardSnapshot};
use super::server::{config_json, err_json, obj, ErrCode};

/// A client-facing request the router dispatches. Both front-ends speak
/// this: TCP connection threads (`super::Server`) and the in-process
/// [`ShardPool`] wrappers.
pub(crate) enum RouterMsg {
    /// Open a session on the least-loaded shard.
    Open { reply: mpsc::Sender<Json> },
    /// Feed audio to an open session (routed to its shard).
    Feed { session: u64, samples: Vec<f32>, enqueued: Instant, reply: mpsc::Sender<Json> },
    /// Finish a session and retire its assignment.
    Finish { session: u64, reply: mpsc::Sender<Json> },
    /// Aggregate per-shard metrics.
    Stats { reply: mpsc::Sender<Json> },
    /// Device/config introspection (served by shard 0).
    Config { reply: mpsc::Sender<Json> },
    /// Stop the router and every worker.
    Shutdown,
}

/// A unit of work queued to one shard's device worker.
enum Job {
    /// Open a session under a router-assigned globally unique id.
    Open { id: u64, reply: mpsc::Sender<Json> },
    /// Stage audio + run the lane-batched device loop.
    Feed { session: u64, samples: Vec<f32>, enqueued: Instant, reply: mpsc::Sender<Json> },
    /// Flush and extract the transcript.
    Finish { session: u64, reply: mpsc::Sender<Json> },
    /// Introspect the engine this worker serves.
    Config { reply: mpsc::Sender<Json> },
    /// Report live status (read-only; never flushes).
    Snapshot { reply: mpsc::Sender<ShardSnapshot> },
    /// Hand back up to `max` not-yet-started sessions for migration.
    Evict { max: usize, reply: mpsc::Sender<Vec<(u64, Vec<f32>)>> },
    /// Re-open a migrated session (buffered audio intact) under its id.
    /// Replies `Ok(())` on success; a worker that cannot open the
    /// session hands the buffer back (`Err(buf)`) so the router can
    /// re-adopt it elsewhere instead of destroying the session.
    /// `returning` marks a bounce-back to the origin shard after a
    /// failed migration — re-booked but not counted as adopted.
    Adopt {
        id: u64,
        buf: Vec<f32>,
        returning: bool,
        reply: mpsc::Sender<Result<(), Vec<f32>>>,
    },
    /// Flush staged work and exit the worker loop.
    Shutdown,
}

impl Job {
    /// The client reply channel this job carries, if any — used to
    /// bounce the request when its shard's queue is saturated.
    fn reply(&self) -> Option<&mpsc::Sender<Json>> {
        match self {
            Job::Open { reply, .. }
            | Job::Feed { reply, .. }
            | Job::Finish { reply, .. }
            | Job::Config { reply } => Some(reply),
            Job::Snapshot { .. } | Job::Evict { .. } | Job::Adopt { .. } | Job::Shutdown => None,
        }
    }
}

/// A feed waiting for its batch to flush.
struct StagedFeed {
    session: u64,
    reply: mpsc::Sender<Json>,
    enqueued: Instant,
}

/// Run the pending batch: pull its sessions out of the map, fuse their
/// ready steps through `Engine::step_batch`, record occupancy/latency,
/// then answer every staged feed with its session's step count + partial.
///
/// A batch-level engine error **poisons** the fused step
/// (`AmBackend::score_step_batch` contract: lane states may have
/// advanced while no audio drained), so the batch's sessions are
/// discarded — reinserting them would let a later feed/finish silently
/// replay consumed audio against advanced state and return a corrupt
/// transcript as success. Every staged feed gets the `internal` error,
/// later ops on those ids get `unknown_session`, and the router is
/// told through the `retire` back-channel to un-book them.
///
/// Known coarseness, acceptable at this layer: if one session was fed
/// twice before the flush (two connections), both replies report the
/// same since-staging step delta; and a batch-level engine error is
/// reported to every staged feed in the batch, not just the failing
/// lane's.
fn flush_batch(
    engine: &Engine,
    sessions: &mut HashMap<u64, Session>,
    batcher: &mut Batcher,
    staged: &mut Vec<StagedFeed>,
    metrics: &mut ServeMetrics,
    retire: &mpsc::Sender<u64>,
) {
    let ids = batcher.take();
    // Pull the batch's sessions out of the map so every lane can be
    // borrowed mutably at once; they go back right after the fused step.
    let mut lanes: Vec<(u64, Session, usize)> = Vec::with_capacity(ids.len());
    for id in ids {
        if let Some(s) = sessions.remove(&id) {
            let steps_before = s.metrics.steps;
            lanes.push((id, s, steps_before));
        }
    }
    let occupancy = lanes.iter().filter(|(_, s, _)| engine.ready_steps(s) > 0).count();
    let t0 = Instant::now();
    let result = {
        let mut refs: Vec<&mut Session> = lanes.iter_mut().map(|(_, s, _)| s).collect();
        engine.step_batch(&mut refs)
    };
    if occupancy > 0 {
        metrics.record_batch(occupancy, t0.elapsed());
    }
    let err = result.err().map(|e| format!("feed failed: {e:#}"));
    for (id, s, steps_before) in lanes {
        let steps = s.metrics.steps - steps_before;
        metrics.steps_executed += steps as u64;
        metrics.audio_seconds += steps as f64 * engine.model_cfg.step_seconds();
        let partial = engine.partial(&s).map(|t| t.text).unwrap_or_default();
        if err.is_none() {
            sessions.insert(id, s);
        } else {
            // Poisoned: discard the session (see the function docs).
            let _ = retire.send(id);
        }
        staged.retain(|f| {
            if f.session != id {
                return true;
            }
            let resp = match &err {
                Some(msg) => err_json(ErrCode::Internal, msg),
                None => obj(&[
                    ("steps", Json::Num(steps as f64)),
                    ("partial", Json::Str(partial.clone())),
                ]),
            };
            metrics.feed_latency.record(f.enqueued.elapsed());
            let _ = f.reply.send(resp);
            false
        });
    }
    // Staged feeds whose session vanished from the map (finished from
    // another connection mid-batch): answer rather than hang the client.
    for f in staged.drain(..) {
        let _ = f
            .reply
            .send(err_json(ErrCode::UnknownSession, "session closed before its batch ran"));
    }
}

/// One shard's device loop: owns its engine, sessions, batcher and
/// metrics; drains jobs FIFO; never blocks sending (replies and the
/// `retire` back-channel are unbounded), so the router can always make
/// progress. The retire channel is deliberately *not* the router's
/// main queue: workers holding a main-queue sender would keep the
/// router alive after every client handle dropped (thread leak).
fn worker_loop(
    shard: usize,
    engine: Engine,
    jobs: mpsc::Receiver<Job>,
    depth: Arc<AtomicUsize>,
    retire: mpsc::Sender<u64>,
) {
    let mut sessions: HashMap<u64, Session> = HashMap::new();
    let mut metrics = ServeMetrics::default();
    let mut batcher = engine.batcher();
    let mut staged: Vec<StagedFeed> = Vec::new();
    loop {
        // Enforce the wait budget even under sustained job traffic: a
        // queued message makes recv_timeout return Ok without ever timing
        // out, so an expired partial batch must flush here, not just on
        // the Timeout arm.
        if !staged.is_empty() && batcher.wait_budget().is_zero() {
            flush_batch(&engine, &mut sessions, &mut batcher, &mut staged, &mut metrics, &retire);
        }
        // Block for the next job; with feeds staged, cap the wait at the
        // batcher's remaining budget so a partial batch still flushes.
        let job = if staged.is_empty() {
            match jobs.recv() {
                Ok(j) => j,
                Err(_) => break,
            }
        } else {
            match jobs.recv_timeout(batcher.wait_budget()) {
                Ok(j) => j,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    flush_batch(&engine, &mut sessions, &mut batcher, &mut staged, &mut metrics, &retire);
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    flush_batch(&engine, &mut sessions, &mut batcher, &mut staged, &mut metrics, &retire);
                    break;
                }
            }
        };
        depth.fetch_sub(1, Ordering::Relaxed);
        match job {
            Job::Shutdown => {
                flush_batch(&engine, &mut sessions, &mut batcher, &mut staged, &mut metrics, &retire);
                break;
            }
            Job::Open { id, reply } => {
                let resp = match engine.open(false) {
                    Ok(s) => {
                        sessions.insert(id, s);
                        metrics.sessions_opened += 1;
                        obj(&[("session", Json::Num(id as f64))])
                    }
                    Err(e) => {
                        // The router booked this id at dispatch; un-book
                        // it so failed opens (fallible PJRT open_state)
                        // don't leak assignments or skew load counts.
                        let _ = retire.send(id);
                        err_json(ErrCode::Internal, &format!("open failed: {e:#}"))
                    }
                };
                let _ = reply.send(resp);
            }
            Job::Feed { session, samples, enqueued, reply } => {
                match sessions.get_mut(&session) {
                    None => {
                        let _ = reply.send(err_json(ErrCode::UnknownSession, "unknown session"));
                    }
                    Some(s) => {
                        engine.push_audio(s, &samples);
                        staged.push(StagedFeed { session, reply, enqueued });
                        // Flush when the batch is full — or when every open
                        // session on this shard is already staged, since no
                        // further lane can arrive before some staged client
                        // unblocks.
                        if batcher.push(session) || batcher.len() >= sessions.len() {
                            flush_batch(
                                &engine,
                                &mut sessions,
                                &mut batcher,
                                &mut staged,
                                &mut metrics,
                                &retire,
                            );
                        }
                    }
                }
            }
            Job::Finish { session, reply } => {
                // Any staged work (this session's included) runs first so
                // the transcript covers all fed audio.
                if !staged.is_empty() {
                    flush_batch(&engine, &mut sessions, &mut batcher, &mut staged, &mut metrics, &retire);
                }
                batcher.remove(session);
                let resp = match sessions.remove(&session) {
                    None => err_json(ErrCode::UnknownSession, "unknown session"),
                    Some(mut s) => match engine.finish(&mut s) {
                        Ok(t) => {
                            metrics.sessions_finished += 1;
                            metrics.compute_seconds += s.metrics.compute_s;
                            obj(&[
                                ("text", Json::Str(t.text)),
                                ("score", Json::Num(t.score as f64)),
                                ("rtf", Json::Num(s.metrics.rtf())),
                                ("steps", Json::Num(s.metrics.steps as f64)),
                                ("batch_occupancy", Json::Num(s.metrics.avg_batch_occupancy())),
                            ])
                        }
                        Err(e) => err_json(ErrCode::Internal, &format!("finish failed: {e:#}")),
                    },
                };
                let _ = reply.send(resp);
            }
            Job::Config { reply } => {
                let _ = reply.send(config_json(&engine));
            }
            Job::Snapshot { reply } => {
                let _ = reply.send(ShardSnapshot {
                    shard,
                    open_sessions: sessions.len(),
                    queue_depth: depth.load(Ordering::Relaxed),
                    serve: metrics.clone(),
                });
            }
            Job::Evict { max, reply } => {
                // Only sessions that have not started decoding and have
                // no feed in flight (not staged) may leave this shard.
                let mut ids: Vec<u64> = sessions
                    .iter()
                    .filter(|(id, s)| s.metrics.steps == 0 && !batcher.contains(**id))
                    .map(|(id, _)| *id)
                    .collect();
                ids.sort_unstable();
                ids.truncate(max);
                let mut moved = Vec::with_capacity(ids.len());
                for id in ids {
                    if let Some(s) = sessions.remove(&id) {
                        match s.into_buffered() {
                            Ok(buf) => moved.push((id, buf)),
                            // Defensive: a pinned session goes back.
                            Err(s) => {
                                sessions.insert(id, s);
                            }
                        }
                    }
                }
                // The evicted sessions are no longer this shard's opens;
                // the adopting shard re-counts them, so per-shard
                // opened/finished stay balanced and the aggregate nets
                // out (−1 here, +1 there).
                metrics.sessions_opened -= moved.len() as u64;
                let _ = reply.send(moved);
            }
            Job::Adopt { id, buf, returning, reply } => {
                let resp = match engine.open(false) {
                    Ok(mut s) => {
                        engine.push_audio(&mut s, &buf);
                        sessions.insert(id, s);
                        // A bounce-back to the origin shard is not a
                        // migration — don't report phantom adoptions.
                        if !returning {
                            metrics.sessions_adopted += 1;
                        }
                        // Adopted sessions count as this shard's opens
                        // (the evicting shard un-counted them), so this
                        // shard's eventual finish balances locally.
                        metrics.sessions_opened += 1;
                        Ok(())
                    }
                    // Hand the buffer back for re-adoption elsewhere.
                    Err(_) => Err(buf),
                };
                let _ = reply.send(resp);
            }
        }
    }
}

/// One worker's router-side handle.
struct ShardHandle {
    tx: mpsc::SyncSender<Job>,
    depth: Arc<AtomicUsize>,
}

/// Router state: session→shard assignments plus per-shard load and
/// liveness, all router-thread-local so *initial* assignment (`pick`)
/// is a pure function of the request sequence; migration eligibility
/// additionally depends on worker-side flush timing, so placement
/// after rebalancing is best-effort, never transcript-affecting.
/// (Liveness only changes when a worker dies — an abnormal event that
/// is then surfaced, not hidden.)
struct Router {
    shards: Vec<ShardHandle>,
    /// A worker whose job channel disconnected (thread died). Dead
    /// shards are excluded from `pick`/`rebalance` so one crashed
    /// worker does not black-hole new sessions.
    dead: Vec<bool>,
    /// Per-shard count of client jobs bounced with `backpressure`
    /// (router-side; folded into stats snapshots so shed load shows).
    rejected: Vec<u64>,
    assign: HashMap<u64, usize>,
    open_count: Vec<usize>,
    next_id: u64,
    rebalance_threshold: usize,
}

impl Router {
    /// Forward a router-internal job (snapshot/evict/adopt/shutdown),
    /// accounting its queue-depth slot. Blocking is acceptable here:
    /// these jobs are part of a serialized router transaction and the
    /// worker always drains. A dead worker drops the job (and with it
    /// any reply sender), which a waiting peer observes as a dropped
    /// request.
    fn send(&mut self, shard: usize, job: Job) {
        let h = &self.shards[shard];
        h.depth.fetch_add(1, Ordering::Relaxed);
        if h.tx.send(job).is_err() {
            h.depth.fetch_sub(1, Ordering::Relaxed);
            self.dead[shard] = true;
        }
    }

    /// Forward a client-facing job without ever blocking the router on
    /// one saturated shard (head-of-line isolation): a full worker
    /// queue bounces the request with `backpressure` — the hot shard's
    /// clients back off while every other shard keeps routing. Returns
    /// whether the job was enqueued.
    fn try_send_client(&mut self, shard: usize, job: Job) -> bool {
        let h = &self.shards[shard];
        h.depth.fetch_add(1, Ordering::Relaxed);
        let (bounced, code, msg) = match h.tx.try_send(job) {
            Ok(()) => return true,
            Err(mpsc::TrySendError::Full(j)) => {
                self.rejected[shard] += 1;
                (j, ErrCode::Backpressure, "shard queue full")
            }
            Err(mpsc::TrySendError::Disconnected(j)) => {
                self.dead[shard] = true;
                (j, ErrCode::Internal, "shard worker unavailable")
            }
        };
        self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
        if let Some(reply) = bounced.reply() {
            let _ = reply.send(err_json(code, msg));
        }
        false
    }

    /// Least-loaded *live* shard by open sessions, lowest index on ties
    /// — deterministic given the open/finish sequence. Falls back to
    /// shard 0 only when every worker is dead (the open then bounces
    /// with `internal` rather than silently hanging).
    fn pick(&self) -> usize {
        (0..self.shards.len())
            .filter(|&i| !self.dead[i])
            .min_by_key(|&i| (self.open_count[i], i))
            .unwrap_or(0)
    }

    /// Migrate queued (not-yet-started) sessions off the hottest shard
    /// when the open-session imbalance reaches the threshold. One
    /// hot→cold round per trigger bounds the router stall.
    fn rebalance(&mut self) {
        let thr = self.rebalance_threshold;
        if thr == 0 || self.shards.len() < 2 {
            return;
        }
        // Dead shards neither donate (their queue is gone) nor receive.
        let Some(hot) = (0..self.shards.len())
            .filter(|&i| !self.dead[i])
            .max_by_key(|&i| self.open_count[i])
        else {
            return;
        };
        let cold = self.pick();
        if self.dead[cold] || hot == cold {
            return;
        }
        let diff = self.open_count[hot] - self.open_count[cold];
        if diff < thr {
            return;
        }
        let want = diff / 2;
        if want == 0 {
            return;
        }
        let (tx, rx) = mpsc::channel();
        self.send(hot, Job::Evict { max: want, reply: tx });
        let Ok(moved) = rx.recv() else { return };
        for (id, buf) in moved {
            match self.adopt_on(cold, id, buf, false) {
                Ok(()) => {
                    self.assign.insert(id, cold);
                    self.open_count[hot] -= 1;
                    self.open_count[cold] += 1;
                }
                // Cold shard refused but returned the buffer: put the
                // session back where it came from (assignment and
                // open_count for `hot` are still in place).
                Err(Some(buf)) => {
                    if self.adopt_on(hot, id, buf, true).is_err() {
                        self.assign.remove(&id);
                        self.open_count[hot] -= 1;
                    }
                }
                // The worker died holding the buffer: the session is
                // unrecoverable; later ops see unknown_session.
                Err(None) => {
                    self.assign.remove(&id);
                    self.open_count[hot] -= 1;
                }
            }
        }
    }

    /// Ask `shard` to adopt a migrated session. `Ok(())` on success,
    /// `Err(Some(buf))` when the worker refused and handed the buffer
    /// back, `Err(None)` when the worker died with it.
    fn adopt_on(
        &mut self,
        shard: usize,
        id: u64,
        buf: Vec<f32>,
        returning: bool,
    ) -> Result<(), Option<Vec<f32>>> {
        let (tx, rx) = mpsc::channel();
        self.send(shard, Job::Adopt { id, buf, returning, reply: tx });
        match rx.recv() {
            Ok(Ok(())) => Ok(()),
            Ok(Err(buf)) => Err(Some(buf)),
            Err(_) => Err(None),
        }
    }

    /// Probe every worker for its live status. Broadcast first, then
    /// collect, so the router stalls for the busiest single worker's
    /// drain (max across shards), not the sum over all of them; workers
    /// answer snapshots without flushing anything.
    fn snapshot(&mut self) -> ShardMetrics {
        let mut pending = Vec::with_capacity(self.shards.len());
        for i in 0..self.shards.len() {
            let (tx, rx) = mpsc::channel();
            self.send(i, Job::Snapshot { reply: tx });
            pending.push(rx);
        }
        let mut shards = Vec::with_capacity(pending.len());
        for rx in pending {
            if let Ok(snap) = rx.recv() {
                shards.push(snap);
            }
        }
        // Workers can't see router-side bounces; fold them in here so
        // `rejected` in summaries reflects shed load.
        for snap in shards.iter_mut() {
            snap.serve.rejected_backpressure += self.rejected[snap.shard];
        }
        ShardMetrics { shards }
    }
}

/// Render the aggregated stats payload (the `stats` op's response):
/// a merged summary plus one entry per shard. `workers` is the
/// configured pool size; a `responding` count below it surfaces dead
/// workers instead of silently shrinking the report.
fn stats_json(m: &ShardMetrics, workers: usize) -> Json {
    let shards: Vec<Json> = m
        .shards
        .iter()
        .map(|s| {
            obj(&[
                ("shard", Json::Num(s.shard as f64)),
                ("sessions", Json::Num(s.open_sessions as f64)),
                ("queue", Json::Num(s.queue_depth as f64)),
                ("adopted", Json::Num(s.serve.sessions_adopted as f64)),
                ("summary", Json::Str(s.serve.summary())),
            ])
        })
        .collect();
    obj(&[
        // The human-readable line: aggregate counters plus a per-shard
        // sessions/queue/rtf appendix (ShardMetrics::summary).
        ("summary", Json::Str(m.summary())),
        ("workers", Json::Num(workers as f64)),
        ("responding", Json::Num(m.shards.len() as f64)),
        ("imbalance", Json::Num(m.imbalance() as f64)),
        ("shards", Json::Arr(shards)),
    ])
}

/// The router loop: serializes assignment decisions, forwards work, and
/// answers session-less requests itself. `retire` is the workers'
/// un-book back-channel (failed opens), drained lazily before each
/// decision so load counts stay honest.
fn router_loop(jobs: mpsc::Receiver<RouterMsg>, retire: mpsc::Receiver<u64>, mut r: Router) {
    loop {
        let msg = match jobs.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        while let Ok(session) = retire.try_recv() {
            if let Some(shard) = r.assign.remove(&session) {
                r.open_count[shard] = r.open_count[shard].saturating_sub(1);
            }
        }
        match msg {
            RouterMsg::Open { reply } => {
                let id = r.next_id;
                r.next_id += 1;
                let shard = r.pick();
                // Commit the assignment only once the job is enqueued —
                // a bounced open leaves no phantom session behind. A
                // worker-side engine.open() failure after enqueue
                // (fallible PJRT open_state) comes back as a Retire
                // notification and is un-booked below.
                if r.try_send_client(shard, Job::Open { id, reply }) {
                    r.assign.insert(id, shard);
                    r.open_count[shard] += 1;
                    r.rebalance();
                }
            }
            RouterMsg::Feed { session, samples, enqueued, reply } => {
                match r.assign.get(&session) {
                    None => {
                        let _ = reply.send(err_json(ErrCode::UnknownSession, "unknown session"));
                    }
                    Some(&shard) => {
                        // A bounce answers the client itself; nothing
                        // reached the shard, so ordering is preserved.
                        r.try_send_client(shard, Job::Feed { session, samples, enqueued, reply });
                    }
                }
            }
            RouterMsg::Finish { session, reply } => match r.assign.get(&session).copied() {
                None => {
                    let _ = reply.send(err_json(ErrCode::UnknownSession, "unknown session"));
                }
                Some(shard) => {
                    // Retire the session only if the finish was actually
                    // enqueued; on a bounce the client retries against a
                    // still-open session.
                    if r.try_send_client(shard, Job::Finish { session, reply }) {
                        r.assign.remove(&session);
                        r.open_count[shard] -= 1;
                        r.rebalance();
                    }
                }
            },
            RouterMsg::Stats { reply } => {
                let workers = r.shards.len();
                let snap = r.snapshot();
                let _ = reply.send(stats_json(&snap, workers));
            }
            RouterMsg::Config { reply } => {
                r.try_send_client(0, Job::Config { reply });
            }
            RouterMsg::Shutdown => break,
        }
    }
    // Stop every worker (explicit shutdown, or every client handle
    // gone); workers flush their staged batches before exiting. Routed
    // through `send` so queue-depth accounting stays balanced.
    for i in 0..r.shards.len() {
        r.send(i, Job::Shutdown);
    }
}

/// What shard 0 hands back to [`ShardPool::start`] once the engine is
/// built: the policy, the worker seeds, and its own job channel.
struct Init {
    shard_cfg: ShardConfig,
    seeds: Vec<WorkerSeed>,
    tx0: mpsc::SyncSender<Job>,
    depth0: Arc<AtomicUsize>,
}

/// A finished session's transcript and serving metrics, as reported by
/// [`ShardPool::finish`].
#[derive(Debug, Clone)]
pub struct Finished {
    /// The decoded transcript.
    pub text: String,
    /// Total hypothesis score (acoustic + LM + penalties).
    pub score: f64,
    /// Real-time factor over the session's compute.
    pub rtf: f64,
    /// Decoding steps executed.
    pub steps: usize,
    /// Mean lanes per fused step this session shared.
    pub batch_occupancy: f64,
}

/// In-process handle to a sharded serving stack: a router thread over
/// `ShardConfig::workers` device workers, each owning its shard of
/// sessions over the shared model. The TCP [`super::Server`] is a thin
/// protocol front-end over this; tests and examples drive it directly
/// (no sockets, no JSON float round-trips — the cross-shard parity
/// suite needs bit-exact audio in and scores out).
///
/// Cloning the pool clones the client handle, not the workers; any
/// clone may issue requests concurrently.
#[derive(Clone)]
pub struct ShardPool {
    tx: mpsc::SyncSender<RouterMsg>,
    workers: usize,
}

impl ShardPool {
    /// Build the engine on shard 0's thread (PJRT handles are not
    /// `Send`), seed `engine.shard_cfg.workers - 1` further workers from
    /// it, and start the router. Blocks until the engine is built so
    /// construction errors surface here, exactly like `Server::start`.
    pub fn start(
        make_engine: impl FnOnce() -> Result<Engine> + Send + 'static,
        queue_depth: usize,
    ) -> Result<ShardPool> {
        let (router_tx, router_rx) = mpsc::sync_channel::<RouterMsg>(queue_depth);
        let (retire_tx, retire_rx) = mpsc::channel::<u64>();
        let (init_tx, init_rx) = mpsc::channel::<Result<Init, String>>();
        let shard0_retire = retire_tx.clone();
        std::thread::Builder::new()
            .name("asrpu-shard-0".into())
            .spawn(move || {
                let engine = match make_engine() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = init_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                let shard_cfg = engine.shard_cfg.clone();
                let mut seeds = Vec::new();
                for _ in 1..shard_cfg.workers {
                    match engine.clone_worker() {
                        Some(seed) => seeds.push(seed),
                        // The builder rejects this combination; defend
                        // against hand-assembled engines anyway.
                        None => {
                            let _ = init_tx.send(Err(format!(
                                "backend '{}' cannot serve {} workers",
                                engine.backend().name(),
                                shard_cfg.workers
                            )));
                            return;
                        }
                    }
                }
                let (tx0, rx0) = mpsc::sync_channel::<Job>(queue_depth);
                let depth0 = Arc::new(AtomicUsize::new(0));
                let _ = init_tx.send(Ok(Init {
                    shard_cfg,
                    seeds,
                    tx0,
                    depth0: Arc::clone(&depth0),
                }));
                worker_loop(0, engine, rx0, depth0, shard0_retire);
            })
            .context("spawning shard 0")?;
        let init = match init_rx.recv() {
            Ok(Ok(init)) => init,
            Ok(Err(msg)) => anyhow::bail!("engine init failed: {msg}"),
            Err(_) => anyhow::bail!("engine init thread died"),
        };
        let mut handles = vec![ShardHandle { tx: init.tx0, depth: init.depth0 }];
        for (i, seed) in init.seeds.into_iter().enumerate() {
            let shard = i + 1;
            let (tx, rx) = mpsc::sync_channel::<Job>(queue_depth);
            let depth = Arc::new(AtomicUsize::new(0));
            let worker_depth = Arc::clone(&depth);
            let worker_retire = retire_tx.clone();
            std::thread::Builder::new()
                .name(format!("asrpu-shard-{shard}"))
                .spawn(move || {
                    worker_loop(shard, seed.into_engine(), rx, worker_depth, worker_retire)
                })
                .with_context(|| format!("spawning shard {shard}"))?;
            handles.push(ShardHandle { tx, depth });
        }
        let workers = handles.len();
        let router = Router {
            shards: handles,
            dead: vec![false; workers],
            rejected: vec![0; workers],
            assign: HashMap::new(),
            open_count: vec![0; workers],
            next_id: 1,
            rebalance_threshold: init.shard_cfg.rebalance_threshold,
        };
        // The start-scope retire_tx drops here with the function; only
        // worker clones remain, so the retire channel dies with the
        // workers, never the other way around.
        drop(retire_tx);
        std::thread::Builder::new()
            .name("asrpu-router".into())
            .spawn(move || router_loop(router_rx, retire_rx, router))
            .context("spawning router")?;
        Ok(ShardPool { tx: router_tx, workers })
    }

    /// Number of device workers behind this pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// A request sender for front-ends that manage their own replies
    /// (the TCP connection threads).
    pub(crate) fn sender(&self) -> mpsc::SyncSender<RouterMsg> {
        self.tx.clone()
    }

    fn call(&self, make: impl FnOnce(mpsc::Sender<Json>) -> RouterMsg) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(make(tx))
            .map_err(|_| anyhow::anyhow!("pool is shut down"))?;
        let resp = rx.recv().context("router dropped request")?;
        Self::ok_or_err(resp)
    }

    /// Turn a protocol error payload into an `Err` carrying its code.
    fn ok_or_err(resp: Json) -> Result<Json> {
        if let Some(e) = resp.get("error") {
            let code = e.get("code").and_then(Json::as_str).unwrap_or("internal");
            let msg = e.get("message").and_then(Json::as_str).unwrap_or("");
            anyhow::bail!("{code}: {msg}");
        }
        Ok(resp)
    }

    /// Open a session; returns its globally unique id.
    pub fn open(&self) -> Result<u64> {
        let r = self.call(|reply| RouterMsg::Open { reply })?;
        r.get("session")
            .and_then(Json::as_f64)
            .map(|v| v as u64)
            .context("malformed open reply")
    }

    /// Feed audio, blocking until the session's batch flushes; returns
    /// the steps run since staging and the current partial transcript.
    pub fn feed(&self, session: u64, samples: &[f32]) -> Result<(usize, String)> {
        let rx = self.feed_async(session, samples)?;
        let resp = rx.recv().context("router dropped feed")?;
        Self::parse_feed(resp)
    }

    /// Stage a feed without blocking: the receiver yields the reply when
    /// the session's batch flushes (interpret it with
    /// [`Self::parse_feed`]). Fan-out callers stage one feed per session
    /// and then collect, letting the device fuse them into one batch.
    pub fn feed_async(&self, session: u64, samples: &[f32]) -> Result<mpsc::Receiver<Json>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Feed {
                session,
                samples: samples.to_vec(),
                enqueued: Instant::now(),
                reply: tx,
            })
            .map_err(|_| anyhow::anyhow!("pool is shut down"))?;
        Ok(rx)
    }

    /// Interpret a feed reply from [`Self::feed_async`].
    pub fn parse_feed(resp: Json) -> Result<(usize, String)> {
        let r = Self::ok_or_err(resp)?;
        let steps = r
            .get("steps")
            .and_then(Json::as_usize)
            .context("malformed feed reply")?;
        let partial = r
            .get("partial")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string();
        Ok((steps, partial))
    }

    /// Finish a session: flush remaining audio and return the final
    /// transcript + metrics.
    pub fn finish(&self, session: u64) -> Result<Finished> {
        let r = self.call(|reply| RouterMsg::Finish { session, reply })?;
        Ok(Finished {
            text: r
                .get("text")
                .and_then(Json::as_str)
                .context("malformed finish reply")?
                .to_string(),
            score: r.get("score").and_then(Json::as_f64).unwrap_or(0.0),
            rtf: r.get("rtf").and_then(Json::as_f64).unwrap_or(0.0),
            steps: r.get("steps").and_then(Json::as_usize).unwrap_or(0),
            batch_occupancy: r.get("batch_occupancy").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }

    /// Aggregated per-shard serving metrics (the `stats` op's payload).
    pub fn stats(&self) -> Result<Json> {
        self.call(|reply| RouterMsg::Stats { reply })
    }

    /// Device/config introspection (the `config` op's payload).
    pub fn config(&self) -> Result<Json> {
        self.call(|reply| RouterMsg::Config { reply })
    }

    /// Stop the router and every worker (idempotent). Uses a blocking
    /// send so the request survives a momentarily full queue — the
    /// router always drains, so the wait is bounded by one queue's
    /// in-flight work; a router that already exited is a no-op.
    pub fn shutdown(&self) {
        let _ = self.tx.send(RouterMsg::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::TdsModel;
    use crate::config::{BatchConfig, ModelConfig};
    use crate::synth::Synthesizer;
    use crate::util::rng::Rng;

    fn pool(workers: usize, threshold: usize) -> ShardPool {
        ShardPool::start(
            move || {
                Ok(Engine::builder()
                    .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                    .batch(BatchConfig::default())
                    .shards(crate::config::ShardConfig {
                        workers,
                        rebalance_threshold: threshold,
                    })
                    .build()?)
            },
            64,
        )
        .unwrap()
    }

    fn utterance(seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        Synthesizer::default().render(&[1, 4], &mut rng).samples
    }

    #[test]
    fn single_worker_pool_round_trip() {
        let p = pool(1, 2);
        assert_eq!(p.workers(), 1);
        let id = p.open().unwrap();
        let audio = utterance(3);
        let (steps, _partial) = p.feed(id, &audio).unwrap();
        assert!(steps > 0);
        let done = p.finish(id).unwrap();
        assert!(!done.text.is_empty() || done.steps > 0);
        let stats = p.stats().unwrap();
        assert_eq!(stats.get("workers").unwrap().as_f64(), Some(1.0));
        assert!(p.finish(id).is_err(), "finished session must be unknown");
        p.shutdown();
    }

    #[test]
    fn rebalance_migrates_queued_sessions_deterministically() {
        // Deterministic assignment (least-open, lowest index on ties):
        // sessions 1,3 land on shard 0 and 2,4 on shard 1. Finishing 1
        // and 3 empties shard 0 → imbalance 2 hits the threshold and the
        // router migrates the lowest queued id (2) to shard 0.
        let p = pool(2, 2);
        let ids: Vec<u64> = (0..4).map(|_| p.open().unwrap()).collect();
        assert_eq!(ids, vec![1, 2, 3, 4]);
        p.finish(1).unwrap();
        p.finish(3).unwrap();
        let stats = p.stats().unwrap();
        let shards = stats.get("shards").unwrap().as_arr().unwrap();
        let adopted: f64 = shards
            .iter()
            .map(|s| s.get("adopted").unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(adopted, 1.0, "exactly one queued session migrates: {stats:?}");
        assert_eq!(stats.get("imbalance").unwrap().as_f64(), Some(0.0));
        // The migrated session still decodes exactly like a 1-worker
        // engine fed the same audio.
        let reference = Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
            .build()
            .unwrap();
        for id in [2u64, 4] {
            let audio = utterance(10 + id);
            let (t_ref, _) = reference.decode_utterance(&audio).unwrap();
            p.feed(id, &audio).unwrap();
            let done = p.finish(id).unwrap();
            assert_eq!(done.text, t_ref.text, "session {id}");
            assert_eq!(done.score, t_ref.score as f64, "session {id}");
        }
        p.shutdown();
    }

    #[test]
    fn started_sessions_are_pinned() {
        // A session that already ran steps must not migrate even under
        // imbalance: evict candidates are steps == 0 only.
        let p = pool(2, 2);
        let a = p.open().unwrap(); // shard 0
        let b = p.open().unwrap(); // shard 1
        let c = p.open().unwrap(); // shard 0
        // Run steps on every session so all are pinned.
        for &id in &[a, b, c] {
            p.feed(id, &utterance(20 + id)).unwrap();
        }
        // Finishing b empties shard 1 → imbalance 2, but both shard-0
        // sessions are pinned: no migration may occur.
        p.finish(b).unwrap();
        let stats = p.stats().unwrap();
        let shards = stats.get("shards").unwrap().as_arr().unwrap();
        let adopted: f64 = shards
            .iter()
            .map(|s| s.get("adopted").unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(adopted, 0.0, "pinned sessions must not move: {stats:?}");
        for id in [a, c] {
            p.finish(id).unwrap();
        }
        p.shutdown();
    }

    #[test]
    fn multi_worker_pool_reports_per_shard_stats() {
        let p = pool(4, 0);
        let ids: Vec<u64> = (0..8).map(|_| p.open().unwrap()).collect();
        for &id in &ids {
            p.feed(id, &utterance(40 + id)).unwrap();
        }
        let stats = p.stats().unwrap();
        assert_eq!(stats.get("workers").unwrap().as_f64(), Some(4.0));
        let shards = stats.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 4);
        // Deterministic least-loaded assignment: 2 sessions per shard.
        for s in shards {
            assert_eq!(s.get("sessions").unwrap().as_f64(), Some(2.0), "{stats:?}");
        }
        for &id in &ids {
            p.finish(id).unwrap();
        }
        p.shutdown();
    }
}
