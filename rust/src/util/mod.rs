//! Infrastructure the offline crate set doesn't provide: seeded RNG, a
//! JSON codec, CLI parsing, table/figure rendering, binary tensor I/O and
//! a property-testing mini-framework.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
pub mod tensor_io;
