//! Aligned-text table rendering for the `report` subcommands — every paper
//! table/figure is regenerated as one of these (plus CSV export).

/// A simple column-aligned table with a title and optional footnote.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub footnote: Option<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            footnote: None,
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: build a row from display-ables.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                // Right-align numeric-looking cells, left-align text.
                let numeric = cell
                    .chars()
                    .all(|c| c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | '%' | 'e'));
                if numeric && !cell.is_empty() {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                } else {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        if let Some(note) = &self.footnote {
            out.push_str(&format!("note: {note}\n"));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Render a horizontal ASCII bar chart (used for figure reproductions).
pub fn bar_chart(title: &str, items: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = items.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max);
    let label_w = items.iter().map(|(l, _)| l.chars().count()).max().unwrap_or(0);
    let mut out = format!("== {title} ==\n");
    for (label, value) in items {
        let bar_len = if max > 0.0 {
            ((value / max) * width as f64).round() as usize
        } else {
            0
        };
        out.push_str(&format!(
            "{label:<label_w$} | {} {value:.3} {unit}\n",
            "#".repeat(bar_len)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["alpha".into(), "1.25".into()]);
        t.row(&["b".into(), "100".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("alpha"));
        let lines: Vec<&str> = r.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["has,comma".into()]);
        assert_eq!(t.to_csv(), "a\n\"has,comma\"\n");
    }

    #[test]
    fn bar_chart_scales() {
        let c = bar_chart(
            "sizes",
            &[("big".into(), 10.0), ("small".into(), 5.0)],
            "KB",
            10,
        );
        assert!(c.contains("##########"));
        assert!(c.contains("#####"));
    }
}
