//! Minimal command-line parsing (no `clap` in the offline crate set).
//!
//! Grammar: `asrpu <subcommand> [--flag] [--key value]... [positional]...`.
//! Typed accessors return `anyhow` errors with the flag name so `main` can
//! print actionable messages.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, `--key value` options, bare `--flag`
/// switches, and positionals, in the order given.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Option keys that take a value; everything else starting with `--` is a
/// boolean switch.
pub fn parse(argv: &[String], value_keys: &[&str]) -> Result<Args> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            if let Some((k, v)) = key.split_once('=') {
                args.opts.insert(k.to_string(), v.to_string());
            } else if value_keys.contains(&key) {
                let v = it
                    .next()
                    .ok_or_else(|| anyhow!("--{key} requires a value"))?;
                args.opts.insert(key.to_string(), v.clone());
            } else {
                args.flags.push(key.to_string());
            }
        } else if args.subcommand.is_none() && args.positional.is_empty() {
            args.subcommand = Some(a.clone());
        } else {
            args.positional.push(a.clone());
        }
    }
    Ok(args)
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Parse `a..b` or `a..b..step` integer ranges used by sweep commands.
    pub fn range_or(&self, name: &str, default: (usize, usize, usize)) -> Result<Vec<usize>> {
        let (lo, hi, step) = match self.get(name) {
            None => default,
            Some(v) => {
                let parts: Vec<&str> = v.split("..").collect();
                match parts.as_slice() {
                    [a, b] => (parse_usize(name, a)?, parse_usize(name, b)?, 1),
                    [a, b, s] => (
                        parse_usize(name, a)?,
                        parse_usize(name, b)?,
                        parse_usize(name, s)?,
                    ),
                    _ => bail!("--{name} expects 'lo..hi' or 'lo..hi..step', got '{v}'"),
                }
            }
        };
        if step == 0 || lo > hi {
            bail!("--{name}: invalid range {lo}..{hi}..{step}");
        }
        Ok((lo..=hi).step_by(step).collect())
    }
}

fn parse_usize(name: &str, v: &str) -> Result<usize> {
    v.parse()
        .with_context(|| format!("--{name}: '{v}' is not an integer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags_positionals() {
        let a = parse(&argv("report fig11 --config paper --verbose"), &["config"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("report"));
        assert_eq!(a.positional, vec!["fig11"]);
        assert_eq!(a.get("config"), Some("paper"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&argv("decode --beam=24"), &[]).unwrap();
        assert_eq!(a.get("beam"), Some("24"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&argv("x --n 8 --t 2.5"), &["n", "t"]).unwrap();
        assert_eq!(a.usize_or("n", 1).unwrap(), 8);
        assert_eq!(a.f64_or("t", 0.0).unwrap(), 2.5);
        assert_eq!(a.usize_or("absent", 7).unwrap(), 7);
        assert!(a.usize_or("t", 0).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&argv("x --config"), &["config"]).is_err());
    }

    #[test]
    fn ranges() {
        let a = parse(&argv("x --pes 2..8..2"), &["pes"]).unwrap();
        assert_eq!(a.range_or("pes", (1, 1, 1)).unwrap(), vec![2, 4, 6, 8]);
        let b = parse(&argv("x --pes 1..3"), &["pes"]).unwrap();
        assert_eq!(b.range_or("pes", (1, 1, 1)).unwrap(), vec![1, 2, 3]);
        let c = parse(&argv("x"), &[]).unwrap();
        assert_eq!(c.range_or("pes", (4, 6, 1)).unwrap(), vec![4, 5, 6]);
    }
}
