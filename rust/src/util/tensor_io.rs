//! Binary tensor container shared with the python build path.
//!
//! `python/compile/aot.py` writes `artifacts/weights.bin` in this format;
//! the Rust side loads it both for native inference ([`crate::am`]) and to
//! feed weight parameters into the PJRT executable ([`crate::runtime`]).
//!
//! Layout (little-endian):
//! ```text
//! magic   : 8 bytes  = b"ASRPUTNS"
//! count   : u32      — number of tensors
//! per tensor:
//!   name_len : u32, name : utf-8 bytes
//!   ndim     : u32, dims : u32 × ndim
//!   dtype    : u32   (0 = f32, 1 = i8, 2 = u32)
//!   byte_len : u64, data : bytes (f32/u32 little-endian or raw i8)
//! ```
//!
//! The `u32` dtype is Rust-side only (session snapshots in
//! `coordinator::snapshot` use it for ids and counters); the python
//! exporter writes f32 weights exclusively, so artifact files never
//! contain it.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"ASRPUTNS";

/// Split a `u64` into `[lo, hi]` u32 words — the lossless encoding
/// 64-bit counters use inside `u32` tensors (session snapshots).
pub fn u64_words(v: u64) -> [u32; 2] {
    [v as u32, (v >> 32) as u32]
}

/// Reassemble a `u64` from its `[lo, hi]` words.
pub fn u64_from_words(lo: u32, hi: u32) -> u64 {
    (hi as u64) << 32 | lo as u64
}

/// A named dense tensor (f32 or i8 payload).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I8(Vec<i8>),
    U32(Vec<u32>),
}

impl Tensor {
    pub fn f32(name: impl Into<String>, dims: Vec<usize>, data: Vec<f32>) -> Self {
        let t = Tensor {
            name: name.into(),
            dims,
            data: TensorData::F32(data),
        };
        t.validate().expect("invalid tensor");
        t
    }

    pub fn u32(name: impl Into<String>, dims: Vec<usize>, data: Vec<u32>) -> Self {
        let t = Tensor {
            name: name.into(),
            dims,
            data: TensorData::U32(data),
        };
        t.validate().expect("invalid tensor");
        t
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn validate(&self) -> Result<()> {
        let len = match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I8(v) => v.len(),
            TensorData::U32(v) => v.len(),
        };
        if len != self.numel() {
            bail!(
                "tensor '{}': dims {:?} imply {} elements, payload has {}",
                self.name,
                self.dims,
                self.numel(),
                len
            );
        }
        Ok(())
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor '{}' is not f32", self.name),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match &self.data {
            TensorData::U32(v) => Ok(v),
            _ => bail!("tensor '{}' is not u32", self.name),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            TensorData::I8(v) => Ok(v),
            _ => bail!("tensor '{}' is not i8", self.name),
        }
    }
}

/// An ordered collection of named tensors.
#[derive(Debug, Default, Clone)]
pub struct TensorFile {
    pub tensors: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: Tensor) {
        self.index.insert(t.name.clone(), self.tensors.len());
        self.tensors.push(t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn require(&self, name: &str) -> Result<&Tensor> {
        self.get(name)
            .with_context(|| format!("weights file missing tensor '{name}'"))
    }

    /// Serialize to the container byte format (the exact bytes
    /// [`Self::save`] writes; [`Self::from_bytes`] round-trips them).
    /// Deterministic: tensor order, dims and payload bytes are preserved
    /// verbatim, so equal files encode to equal bytes.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            t.validate()?;
            buf.extend_from_slice(&(t.name.len() as u32).to_le_bytes());
            buf.extend_from_slice(t.name.as_bytes());
            buf.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
            for &d in &t.dims {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
            match &t.data {
                TensorData::F32(v) => {
                    buf.extend_from_slice(&0u32.to_le_bytes());
                    buf.extend_from_slice(&((v.len() * 4) as u64).to_le_bytes());
                    for x in v {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::I8(v) => {
                    buf.extend_from_slice(&1u32.to_le_bytes());
                    buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
                    buf.extend(v.iter().map(|&b| b as u8));
                }
                TensorData::U32(v) => {
                    buf.extend_from_slice(&2u32.to_le_bytes());
                    buf.extend_from_slice(&((v.len() * 4) as u64).to_le_bytes());
                    for x in v {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        Ok(buf)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let buf = self.to_bytes()?;
        std::fs::File::create(path)
            .and_then(|mut f| f.write_all(&buf))
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .and_then(|mut f| f.read_to_end(&mut bytes))
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            let s = bytes
                .get(*pos..*pos + n)
                .context("weights file truncated")?;
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 8)? != MAGIC {
            bail!("bad magic: not an ASRPU tensor file");
        }
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut file = TensorFile::new();
        for _ in 0..count {
            let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
                .context("tensor name not utf-8")?;
            let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            if ndim > 8 {
                bail!("tensor '{name}': ndim {ndim} too large (corrupt file?)");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize);
            }
            let dtype = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
            let byte_len = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
            let payload = take(&mut pos, byte_len)?;
            let data = match dtype {
                0 => {
                    if byte_len % 4 != 0 {
                        bail!("tensor '{name}': f32 payload not multiple of 4");
                    }
                    TensorData::F32(
                        payload
                            .chunks_exact(4)
                            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    )
                }
                1 => TensorData::I8(payload.iter().map(|&b| b as i8).collect()),
                2 => {
                    if byte_len % 4 != 0 {
                        bail!("tensor '{name}': u32 payload not multiple of 4");
                    }
                    TensorData::U32(
                        payload
                            .chunks_exact(4)
                            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    )
                }
                d => bail!("tensor '{name}': unknown dtype {d}"),
            };
            let t = Tensor { name, dims, data };
            t.validate()?;
            file.push(t);
        }
        if pos != bytes.len() {
            bail!("trailing bytes after last tensor");
        }
        Ok(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_in_memory() {
        let mut f = TensorFile::new();
        f.push(Tensor::f32("w", vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        f.push(Tensor {
            name: "q".into(),
            dims: vec![4],
            data: TensorData::I8(vec![-1, 0, 1, 127]),
        });
        let dir = std::env::temp_dir().join(format!("asrpu-tio-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        f.save(&path).unwrap();
        let g = TensorFile::load(&path).unwrap();
        assert_eq!(g.tensors.len(), 2);
        assert_eq!(g.get("w").unwrap(), &f.tensors[0]);
        assert_eq!(g.get("q").unwrap(), &f.tensors[1]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn u32_roundtrip_through_bytes() {
        let mut f = TensorFile::new();
        f.push(Tensor::u32("ids", vec![2, 3], vec![0, 1, u32::MAX, 7, 8, 9]));
        f.push(Tensor::f32("w", vec![1], vec![0.5]));
        let bytes = f.to_bytes().unwrap();
        let g = TensorFile::from_bytes(&bytes).unwrap();
        assert_eq!(g.get("ids").unwrap(), &f.tensors[0]);
        assert_eq!(
            g.get("ids").unwrap().as_u32().unwrap(),
            &[0, 1, u32::MAX, 7, 8, 9]
        );
        assert!(g.get("ids").unwrap().as_f32().is_err());
        assert!(g.get("w").unwrap().as_u32().is_err());
        // to_bytes is deterministic (snapshot checksums rely on it).
        assert_eq!(bytes, g.to_bytes().unwrap());
    }

    #[test]
    fn rejects_corrupt() {
        assert!(TensorFile::from_bytes(b"NOTMAGIC").is_err());
        let mut f = TensorFile::new();
        f.push(Tensor::f32("w", vec![2], vec![1., 2.]));
        let dir = std::env::temp_dir().join(format!("asrpu-tio2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        f.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 3);
        assert!(TensorFile::from_bytes(&bytes).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "invalid tensor")]
    fn dims_payload_mismatch_panics() {
        Tensor::f32("bad", vec![2, 2], vec![1.0]);
    }
}
