//! Tiny property-based testing framework (no `proptest` in the offline
//! crate set).
//!
//! A property is a closure over a [`Gen`] (a seeded RNG wrapper with
//! convenience samplers). [`check`] runs it for N seeded cases and, on
//! failure, retries the same seed with progressively smaller size budgets
//! — a coarse form of shrinking that is enough to produce small
//! counterexamples for the invariants this repo checks (decoder beam
//! invariants, scheduler conservation laws, cache coherence of the
//! simulator's memory models).

use super::rng::Rng;

/// Generation context handed to properties: an RNG plus a size budget.
pub struct Gen {
    pub rng: Rng,
    /// Soft upper bound for "how big" generated values should be; shrink
    /// attempts re-run failing seeds with smaller sizes.
    pub size: usize,
}

impl Gen {
    /// Vec of `len` values in `[0, size)`-scaled magnitude from `f`.
    pub fn vec_of<T>(&mut self, len: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        (0..len).map(|_| f(&mut self.rng)).collect()
    }

    /// A length in `[lo, max(lo, size)]`.
    pub fn len(&mut self, lo: usize) -> usize {
        let hi = self.size.max(lo);
        self.rng.range_i64(lo as i64, hi as i64) as usize
    }

    /// Finite f32 in [-magnitude, magnitude].
    pub fn f32(&mut self, magnitude: f32) -> f32 {
        self.rng.uniform(-magnitude, magnitude)
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.rng.below(n as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Outcome of a property: `Ok(())` or a failure description.
pub type PropResult = Result<(), String>;

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Run `cases` seeded executions of `prop`; panic with the smallest
/// reproduction found (seed + size) on failure.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    // Base seed is fixed: CI determinism beats case diversity here, and the
    // per-case split still gives `cases` independent streams.
    let mut root = Rng::new(0xA5B5_C5D5 ^ name.len() as u64);
    for case in 0..cases {
        let seed = root.next_u64() ^ case as u64;
        let size = 4 + (case * 96) / cases.max(1); // ramp 4 → ~100
        let mut g = Gen {
            rng: Rng::new(seed),
            size,
        };
        if let Err(msg) = prop(&mut g) {
            // Shrink: same seed, smaller sizes.
            let mut min_repro = (size, msg);
            let mut sz = size;
            while sz > 1 {
                sz /= 2;
                let mut g = Gen {
                    rng: Rng::new(seed),
                    size: sz,
                };
                if let Err(m) = prop(&mut g) {
                    min_repro = (sz, m);
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {}): {}",
                min_repro.0, min_repro.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-involution", 50, |g| {
            let n = g.len(0);
            let v = g.vec_of(n, |r| r.next_u32());
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert!(v == w, "double reverse changed vec");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_repro() {
        check("always-fails", 5, |g| {
            let n = g.len(1);
            prop_assert!(n == usize::MAX, "n = {n}");
            Ok(())
        });
    }

    #[test]
    fn sort_idempotent_property() {
        check("sort-idempotent", 30, |g| {
            let n = g.len(0);
            let mut v = g.vec_of(n, |r| r.range_i64(-100, 100));
            v.sort_unstable();
            let once = v.clone();
            v.sort_unstable();
            prop_assert!(v == once, "sort not idempotent");
            Ok(())
        });
    }
}
