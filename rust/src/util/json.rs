//! Minimal JSON codec (no serde in the offline crate set).
//!
//! Supports the full JSON grammar needed by the repo: artifact metadata
//! (`artifacts/meta.json` written by `python/compile/aot.py`), the serve
//! protocol, and report export. Numbers are kept as `f64`; object key
//! order is preserved (insertion order) so emitted files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects keep insertion order via a parallel key list.
    Obj(JsonObj),
}

/// An order-preserving JSON object.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

impl Json {
    pub fn obj() -> JsonObj {
        JsonObj::new()
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `get("model.layers")`.
    pub fn get(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_obj()?.get(part)?;
        }
        Some(cur)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(obj) => {
                if obj.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in obj.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no NaN/Inf; emit null like python's json with allow_nan off.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// JSON parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            obj.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: only BMP escapes are emitted by
                            // our own writer; accept lone surrogates as U+FFFD.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-3", "2.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(v.to_string(), text);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn object_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn roundtrip_pretty_reparses() {
        let src = r#"{"model":{"layers":[{"kind":"conv","w":[3,15,15]},{"kind":"fc","w":[1200,1200]}]},"tokens":32}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é中""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é中");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn path_get() {
        let v = Json::parse(r#"{"a":{"b":{"c":42}}}"#).unwrap();
        assert_eq!(v.get("a.b.c").unwrap().as_f64().unwrap(), 42.0);
        assert!(v.get("a.x").is_none());
    }
}
