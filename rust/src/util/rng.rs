//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, and the reproduction needs
//! seeded determinism anyway (synthetic corpora, weight init fallbacks,
//! property-test case generation), so we implement a small, well-known
//! generator: `SplitMix64` for seeding and stream-splitting plus a
//! `xoshiro256**`-style core. All distributions used by the repo (uniform,
//! normal, categorical, permutation) live here.

/// SplitMix64 step — used for seeding and as a standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Deterministic, seedable, splittable.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a named sub-purpose.
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo.wrapping_add(self.below((hi - lo + 1) as u64) as i64)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (one sample; the pair is discarded —
    /// simplicity over throughput; this is never on a hot path).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let p2 = counts[2] as f64 / 30_000.0;
        assert!((p2 - 0.7).abs() < 0.03, "p2={p2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(1234);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
