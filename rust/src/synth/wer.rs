//! Word-error-rate: Levenshtein distance over word sequences, with an
//! accumulator for corpus-level reporting.

/// Minimum edit distance (substitutions + insertions + deletions).
pub fn edit_distance<T: PartialEq>(reference: &[T], hypothesis: &[T]) -> usize {
    let (n, m) = (reference.len(), hypothesis.len());
    if n == 0 {
        return m;
    }
    if m == 0 {
        return n;
    }
    let mut prev: Vec<usize> = (0..=m).collect();
    let mut cur = vec![0usize; m + 1];
    for i in 1..=n {
        cur[0] = i;
        for j in 1..=m {
            let sub = prev[j - 1] + usize::from(reference[i - 1] != hypothesis[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[m]
}

/// Corpus-level WER accumulator.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WerAccum {
    pub edits: usize,
    pub ref_words: usize,
    pub utterances: usize,
    pub exact: usize,
}

impl WerAccum {
    pub fn add<T: PartialEq>(&mut self, reference: &[T], hypothesis: &[T]) {
        let e = edit_distance(reference, hypothesis);
        self.edits += e;
        self.ref_words += reference.len();
        self.utterances += 1;
        if e == 0 {
            self.exact += 1;
        }
    }

    /// WER as a fraction (edits / reference words).
    pub fn wer(&self) -> f64 {
        if self.ref_words == 0 {
            0.0
        } else {
            self.edits as f64 / self.ref_words as f64
        }
    }

    /// Sentence accuracy.
    pub fn sentence_acc(&self) -> f64 {
        if self.utterances == 0 {
            0.0
        } else {
            self.exact as f64 / self.utterances as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn known_distances() {
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 3]), 1); // deletion
        assert_eq!(edit_distance(&[1, 2], &[1, 2, 3]), 1); // insertion
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 9, 3]), 1); // substitution
        assert_eq!(edit_distance::<u32>(&[], &[]), 0);
        assert_eq!(edit_distance(&[1, 2], &[]), 2);
    }

    #[test]
    fn metric_properties() {
        prop::check("edit-distance-metric", 40, |g| {
            let (la, lb, lc) = (g.len(0).min(12), g.len(0).min(12), g.len(0).min(12));
            let a: Vec<u8> = g.vec_of(la, |r| r.below(4) as u8);
            let b: Vec<u8> = g.vec_of(lb, |r| r.below(4) as u8);
            let c: Vec<u8> = g.vec_of(lc, |r| r.below(4) as u8);
            let dab = edit_distance(&a, &b);
            let dba = edit_distance(&b, &a);
            crate::prop_assert!(dab == dba, "not symmetric");
            crate::prop_assert!((dab == 0) == (a == b), "identity violated");
            let dac = edit_distance(&a, &c);
            let dbc = edit_distance(&b, &c);
            crate::prop_assert!(dac <= dab + dbc, "triangle inequality violated");
            crate::prop_assert!(
                dab <= a.len().max(b.len()),
                "distance exceeds max length"
            );
            Ok(())
        });
    }

    #[test]
    fn accumulator() {
        let mut acc = WerAccum::default();
        acc.add(&[1, 2, 3], &[1, 2, 3]);
        acc.add(&[1, 2], &[1, 9]);
        assert_eq!(acc.utterances, 2);
        assert_eq!(acc.exact, 1);
        assert!((acc.wer() - 0.2).abs() < 1e-12);
        assert!((acc.sentence_acc() - 0.5).abs() < 1e-12);
    }
}
