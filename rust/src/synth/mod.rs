//! Synthetic speech: the tone-phoneme protocol (vocabulary, tones, word
//! chain), waveform rendering, and WER scoring. Stands in for the
//! paper's LibriSpeech data — see DESIGN.md §Substitutions.

pub mod audio;
pub mod spec;
pub mod wer;

pub use audio::{Synthesizer, Utterance};
pub use wer::{edit_distance, WerAccum};
