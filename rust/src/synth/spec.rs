//! The synthetic-speech protocol: phoneme→tone mapping, vocabulary,
//! word-sequence distribution.
//!
//! This replaces the paper's LibriSpeech-trained wav2letter stack with a
//! fully deterministic, self-contained equivalent (see DESIGN.md
//! §Substitutions): each of 26 phonemes (rendered as syllables "ba",
//! "de", …) is a dual sine tone; words are fixed 3-syllable
//! concatenations; sentences are sampled from a fixed Markov chain.
//!
//! **Mirrored constants**: `python/compile/data.py` hardcodes the same
//! values — the model is trained on python-synthesized audio and
//! evaluated on rust-synthesized audio, so any drift shows up directly
//! as WER in the end-to-end example.

use crate::lexicon::{Lexicon, TokenSet};
use crate::util::rng::Rng;

/// The 26 syllable names, index = phoneme id - 1 (0 is CTC blank).
pub const SYLLABLES: [&str; 26] = [
    "ba", "be", "bi", "bo", "bu", "da", "de", "di", "do", "du", "ka", "ke",
    "ki", "ko", "ku", "la", "le", "li", "lo", "lu", "ma", "me", "mi", "mo",
    "mu", "na",
];

/// Base frequency of phoneme 0's fundamental (Hz).
pub const F1_BASE: f64 = 300.0;
/// Geometric step between adjacent phonemes (≈2 mel filters apart).
pub const F1_RATIO: f64 = 1.1047;
/// Second partial = F2_MULT × fundamental.
pub const F2_MULT: f64 = 2.1;
/// Tone amplitudes.
pub const AMP1: f64 = 0.35;
pub const AMP2: f64 = 0.25;
/// Phoneme duration range (ms).
pub const DUR_MS: (u32, u32) = (80, 140);
/// Inter-word silence range (ms).
pub const SIL_MS: (u32, u32) = (60, 120);
/// Leading/trailing silence (ms).
pub const EDGE_SIL_MS: u32 = 100;
/// Micro-gap inserted between identical adjacent phonemes (geminates) so
/// the CTC blank can separate them (words 6, 19 and 35 contain repeats).
pub const GEMINATE_GAP_MS: u32 = 30;
/// Additive white noise σ.
pub const NOISE_STD: f64 = 0.01;
/// Vocabulary size.
pub const NUM_WORDS: usize = 40;

/// Tone pair for a phoneme id (1-based; blank has no tone).
pub fn tone(phoneme: u32) -> (f64, f64) {
    assert!((1..=26).contains(&phoneme), "phoneme {phoneme} out of range");
    let f1 = F1_BASE * F1_RATIO.powi(phoneme as i32 - 1);
    (f1, f1 * F2_MULT)
}

/// The token inventory (blank + 26 syllables).
pub fn token_set() -> TokenSet {
    TokenSet::new(SYLLABLES.iter().map(|s| s.to_string()).collect())
}

/// Deterministic vocabulary: word `k` = syllables `s1 s2 s3` with
/// `s1 = k mod 26`, `s2 = (9·(k div 26) + 5·(k mod 26) + 7) mod 26`,
/// `s3 = (13·k + 11) mod 26`. Chosen so all NUM_WORDS pronunciations are
/// distinct (verified by a test and by `Lexicon::build`'s homophone
/// check).
pub fn vocab() -> Vec<(String, Vec<u32>)> {
    (0..NUM_WORDS)
        .map(|k| {
            let s1 = k % 26;
            let s2 = (9 * (k / 26) + 5 * (k % 26) + 7) % 26;
            let s3 = (13 * k + 11) % 26;
            let word = format!("{}{}{}", SYLLABLES[s1], SYLLABLES[s2], SYLLABLES[s3]);
            // Token ids are 1-based (0 = blank).
            (word, vec![s1 as u32 + 1, s2 as u32 + 1, s3 as u32 + 1])
        })
        .collect()
}

/// Build the lexicon for the synthetic vocabulary.
pub fn lexicon() -> Lexicon {
    Lexicon::build(token_set(), &vocab()).expect("synthetic vocab must build")
}

/// Markov chain over words: each word prefers three successors with
/// weights 3:2:1, plus a uniform 10% escape to any word. Sentence length
/// is 3–7 words. Same chain in `python/compile/data.py`.
pub fn successors(word: u32) -> [(u32, f64); 3] {
    let w = word as usize;
    [
        (((w * 5 + 1) % NUM_WORDS) as u32, 3.0),
        (((w * 7 + 2) % NUM_WORDS) as u32, 2.0),
        (((w * 11 + 3) % NUM_WORDS) as u32, 1.0),
    ]
}

/// Sample a sentence (word ids) from the chain.
pub fn sample_sentence(rng: &mut Rng) -> Vec<u32> {
    let len = rng.range_i64(3, 7) as usize;
    let mut words = Vec::with_capacity(len);
    let mut cur = rng.below(NUM_WORDS as u64) as u32;
    words.push(cur);
    for _ in 1..len {
        // 10% escape to uniform, else weighted successor.
        cur = if rng.f64() < 0.1 {
            rng.below(NUM_WORDS as u64) as u32
        } else {
            let succ = successors(cur);
            let weights: Vec<f64> = succ.iter().map(|&(_, w)| w).collect();
            succ[rng.categorical(&weights)].0
        };
        words.push(cur);
    }
    words
}

/// Sample a text corpus for LM estimation (word *names*).
pub fn sample_corpus(n_sentences: usize, seed: u64) -> Vec<Vec<String>> {
    let voc = vocab();
    let mut rng = Rng::new(seed);
    (0..n_sentences)
        .map(|_| {
            sample_sentence(&mut rng)
                .into_iter()
                .map(|w| voc[w as usize].0.clone())
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_has_no_homophones() {
        let v = vocab();
        assert_eq!(v.len(), NUM_WORDS);
        let mut prons: Vec<&Vec<u32>> = v.iter().map(|(_, p)| p).collect();
        prons.sort();
        prons.dedup();
        assert_eq!(prons.len(), NUM_WORDS, "duplicate pronunciations");
        // Lexicon::build would also reject homophones.
        lexicon();
    }

    #[test]
    fn tones_are_ordered_and_below_nyquist() {
        let mut prev = 0.0;
        for p in 1..=26 {
            let (f1, f2) = tone(p);
            assert!(f1 > prev);
            assert!(f2 < 8000.0, "phoneme {p}: f2 = {f2} ≥ Nyquist");
            assert!(f2 <= 7700.0, "phoneme {p}: f2 = {f2} above mel fmax");
            prev = f1;
        }
    }

    #[test]
    fn chain_sentences_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let s = sample_sentence(&mut rng);
            assert!((3..=7).contains(&s.len()));
            assert!(s.iter().all(|&w| (w as usize) < NUM_WORDS));
        }
    }

    #[test]
    fn chain_is_biased_toward_successors() {
        let mut rng = Rng::new(2);
        let mut follow = 0usize;
        let mut total = 0usize;
        for _ in 0..500 {
            let s = sample_sentence(&mut rng);
            for w in s.windows(2) {
                total += 1;
                if successors(w[0]).iter().any(|&(n, _)| n == w[1]) {
                    follow += 1;
                }
            }
        }
        let frac = follow as f64 / total as f64;
        assert!(frac > 0.8, "chain bias too weak: {frac}");
    }

    #[test]
    fn corpus_is_deterministic_per_seed() {
        assert_eq!(sample_corpus(5, 42), sample_corpus(5, 42));
        assert_ne!(sample_corpus(5, 42), sample_corpus(5, 43));
    }
}
