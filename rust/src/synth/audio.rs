//! Waveform synthesis for the tone-phoneme protocol: render a word
//! sequence to 16 kHz samples with per-phoneme dual tones, inter-word
//! silences, amplitude jitter and additive noise.

use super::spec;
use crate::util::rng::Rng;

/// An utterance: samples plus its ground truth.
#[derive(Debug, Clone)]
pub struct Utterance {
    pub samples: Vec<f32>,
    pub words: Vec<u32>,
    pub text: String,
    /// Frame-aligned phoneme labels at `hop`-sample granularity
    /// (token id active at each frame center; blank = 0). Used by the
    /// python trainer (mirrored there) and alignment tests.
    pub frame_labels: Vec<u32>,
}

/// Synthesizer with fixed sample rate and label hop.
#[derive(Debug, Clone)]
pub struct Synthesizer {
    pub sample_rate: usize,
    pub hop: usize,
    pub noise_std: f64,
}

impl Default for Synthesizer {
    fn default() -> Self {
        Synthesizer { sample_rate: 16_000, hop: 160, noise_std: spec::NOISE_STD }
    }
}

impl Synthesizer {
    fn ms(&self, ms: u32) -> usize {
        self.sample_rate * ms as usize / 1000
    }

    /// Render a word sequence. `rng` drives durations, phase, amplitude
    /// jitter and noise.
    pub fn render(&self, words: &[u32], rng: &mut Rng) -> Utterance {
        let voc = spec::vocab();
        // Build the phoneme timeline: (token, n_samples); 0 = silence.
        let mut timeline: Vec<(u32, usize)> = Vec::new();
        timeline.push((0, self.ms(spec::EDGE_SIL_MS)));
        for (i, &w) in words.iter().enumerate() {
            if i > 0 {
                let sil = rng.range_i64(spec::SIL_MS.0 as i64, spec::SIL_MS.1 as i64) as u32;
                timeline.push((0, self.ms(sil)));
            }
            for &ph in &voc[w as usize].1 {
                // Geminate gap: identical adjacent phonemes need a blank
                // in the CTC path; give the decoder real silence.
                if timeline.last().map(|&(t, _)| t) == Some(ph) {
                    timeline.push((0, self.ms(spec::GEMINATE_GAP_MS)));
                }
                let dur = rng.range_i64(spec::DUR_MS.0 as i64, spec::DUR_MS.1 as i64) as u32;
                timeline.push((ph, self.ms(dur)));
            }
        }
        timeline.push((0, self.ms(spec::EDGE_SIL_MS)));

        let total: usize = timeline.iter().map(|&(_, n)| n).sum();
        let mut samples = Vec::with_capacity(total);
        let two_pi = 2.0 * std::f64::consts::PI;
        for &(tok, n) in &timeline {
            if tok == 0 {
                samples.resize(samples.len() + n, 0.0);
                continue;
            }
            let (f1, f2) = spec::tone(tok);
            let amp_jitter = 0.85 + 0.3 * rng.f64();
            let phase1 = rng.f64() * two_pi;
            let phase2 = rng.f64() * two_pi;
            let start = samples.len();
            for t in 0..n {
                let time = (start + t) as f64 / self.sample_rate as f64;
                // 5 ms attack/decay ramp to avoid clicks.
                let ramp_len = self.ms(5).max(1);
                let ramp = (t.min(n - 1 - t) as f64 / ramp_len as f64).min(1.0);
                let v = amp_jitter
                    * ramp
                    * (spec::AMP1 * (two_pi * f1 * time + phase1).sin()
                        + spec::AMP2 * (two_pi * f2 * time + phase2).sin());
                samples.push(v as f32);
            }
        }
        // Additive noise.
        if self.noise_std > 0.0 {
            for s in samples.iter_mut() {
                *s += (rng.normal() as f64 * self.noise_std) as f32;
            }
        }
        // Frame labels at hop granularity (frame center sample).
        let n_frames = samples.len() / self.hop;
        let mut frame_labels = Vec::with_capacity(n_frames);
        let mut bounds = Vec::with_capacity(timeline.len());
        let mut acc = 0usize;
        for &(tok, n) in &timeline {
            bounds.push((acc, acc + n, tok));
            acc += n;
        }
        let mut seg = 0usize;
        for f in 0..n_frames {
            let center = f * self.hop + self.hop / 2;
            while seg + 1 < bounds.len() && center >= bounds[seg].1 {
                seg += 1;
            }
            frame_labels.push(bounds[seg].2);
        }
        let text = words
            .iter()
            .map(|&w| voc[w as usize].0.clone())
            .collect::<Vec<_>>()
            .join(" ");
        Utterance { samples, words: words.to_vec(), text, frame_labels }
    }

    /// Render a random sentence from the word chain.
    pub fn render_random(&self, rng: &mut Rng) -> Utterance {
        let words = spec::sample_sentence(rng);
        self.render(&words, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::Mfcc;

    #[test]
    fn renders_expected_duration() {
        let s = Synthesizer::default();
        let mut rng = Rng::new(1);
        let u = s.render(&[0, 1], &mut rng);
        // 2 words × 3 phonemes × 80–140 ms + 1 gap 60–120 ms + 200 ms edges.
        let lo = 16 * (6 * 80 + 60 + 200);
        let hi = 16 * (6 * 140 + 120 + 200);
        assert!((lo..=hi).contains(&u.samples.len()), "{}", u.samples.len());
        assert!(u.samples.iter().all(|v| v.abs() < 1.2));
    }

    #[test]
    fn labels_cover_all_phonemes() {
        let s = Synthesizer::default();
        let mut rng = Rng::new(2);
        let u = s.render(&[5], &mut rng);
        let voc = spec::vocab();
        let mut seen: Vec<u32> = u.frame_labels.iter().cloned().filter(|&t| t != 0).collect();
        seen.dedup();
        assert_eq!(seen, voc[5].1, "labels should walk the pronunciation");
        // Starts and ends with silence.
        assert_eq!(u.frame_labels[0], 0);
        assert_eq!(*u.frame_labels.last().unwrap(), 0);
    }

    #[test]
    fn tone_energy_lands_in_expected_mel_band() {
        // Phoneme tones must be separable by the front-end: check that
        // the MFCC c0 (energy) of a phoneme is much higher than silence,
        // and that two distinct phonemes give distinct features.
        let s = Synthesizer { noise_std: 0.0, ..Default::default() };
        let mut rng = Rng::new(3);
        let u1 = s.render(&[0], &mut rng);
        let u2 = s.render(&[13], &mut rng);
        let mfcc = Mfcc::new(16_000, 400, 160, 40);
        let f1 = mfcc.extract(&u1.samples);
        let f2 = mfcc.extract(&u2.samples);
        // Compare mid-utterance frames.
        let m1 = &f1[(f1.len() / 80) * 40..(f1.len() / 80) * 40 + 40];
        let m2 = &f2[(f2.len() / 80) * 40..(f2.len() / 80) * 40 + 40];
        let dist: f32 = m1.iter().zip(m2).map(|(a, b)| (a - b).powi(2)).sum();
        assert!(dist > 1.0, "phonemes not separable: {dist}");
    }

    #[test]
    fn deterministic_per_seed() {
        let s = Synthesizer::default();
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let a = s.render(&[1, 2], &mut r1);
        let b = s.render(&[1, 2], &mut r2);
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.frame_labels, b.frame_labels);
    }

    #[test]
    fn render_random_roundtrips_text() {
        let s = Synthesizer::default();
        let mut rng = Rng::new(11);
        let u = s.render_random(&mut rng);
        assert_eq!(u.text.split(' ').count(), u.words.len());
    }
}
