//! n-gram language model (§2.3, §4.3): a backoff bigram LM with ARPA
//! read/write and a Katz-style estimator, plus the per-hypothesis LM
//! state the decoder walks ("each hypothesis contains a link to the
//! language model graph, pointing to the last n-gram").
//!
//! Scores are natural-log probabilities internally; the ARPA text format
//! uses log10 per convention and is converted on read/write.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

pub const SENT_START: &str = "<s>";
pub const SENT_END: &str = "</s>";
pub const UNK: &str = "<unk>";

const LN10: f64 = std::f64::consts::LN_10;

/// Backoff bigram LM.
///
/// `p(w|h) = p2(h,w)` if the bigram exists, else `bo(h) + p1(w)` — all in
/// natural log.
#[derive(Debug, Clone)]
pub struct NgramLm {
    vocab: Vec<String>,
    index: BTreeMap<String, u32>,
    /// Unigram log-probs and backoff weights, indexed by word id.
    uni_logp: Vec<f32>,
    uni_backoff: Vec<f32>,
    /// Bigram log-probs: (h, w) → logp.
    bi_logp: BTreeMap<(u32, u32), f32>,
}

/// Decoder-side LM state: the history word (bigram ⇒ one word).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LmState(pub u32);

impl NgramLm {
    pub fn vocab_len(&self) -> usize {
        self.vocab.len()
    }

    pub fn word_id(&self, w: &str) -> Option<u32> {
        self.index.get(w).copied()
    }

    pub fn word(&self, id: u32) -> &str {
        &self.vocab[id as usize]
    }

    /// Initial state at sentence start.
    pub fn start(&self) -> LmState {
        LmState(self.word_id(SENT_START).expect("LM missing <s>"))
    }

    /// Score a word given the state; returns (ln-prob, next state).
    /// Unknown words map to `<unk>`.
    pub fn score(&self, state: LmState, word_id: u32) -> (f32, LmState) {
        let h = state.0;
        let lp = match self.bi_logp.get(&(h, word_id)) {
            Some(&lp) => lp,
            None => self.uni_backoff[h as usize] + self.uni_logp[word_id as usize],
        };
        (lp, LmState(word_id))
    }

    /// Score the sentence-end from a state.
    pub fn score_end(&self, state: LmState) -> f32 {
        let end = self.word_id(SENT_END).expect("LM missing </s>");
        self.score(state, end).0
    }

    /// Log-prob of a whole sentence (space-separated words), for tests
    /// and perplexity reports.
    pub fn sentence_logp(&self, sentence: &[&str]) -> f32 {
        let unk = self.word_id(UNK).expect("LM missing <unk>");
        let mut state = self.start();
        let mut total = 0.0;
        for w in sentence {
            let id = self.word_id(w).unwrap_or(unk);
            let (lp, next) = self.score(state, id);
            total += lp;
            state = next;
        }
        total + self.score_end(state)
    }

    /// Estimate from a corpus of sentences (each a Vec of words) with
    /// absolute discounting (Katz-style backoff weights).
    pub fn estimate(corpus: &[Vec<String>], discount: f64) -> Result<Self> {
        anyhow::ensure!((0.0..1.0).contains(&discount), "discount must be in [0,1)");
        anyhow::ensure!(!corpus.is_empty(), "empty corpus");
        // Vocabulary: corpus words + specials, deterministic order.
        let mut index: BTreeMap<String, u32> = BTreeMap::new();
        let mut vocab: Vec<String> = Vec::new();
        let intern = |w: &str, vocab: &mut Vec<String>, index: &mut BTreeMap<String, u32>| {
            if let Some(&id) = index.get(w) {
                return id;
            }
            let id = vocab.len() as u32;
            vocab.push(w.to_string());
            index.insert(w.to_string(), id);
            id
        };
        let start = intern(SENT_START, &mut vocab, &mut index);
        let end = intern(SENT_END, &mut vocab, &mut index);
        let _unk = intern(UNK, &mut vocab, &mut index);
        let mut uni_count: BTreeMap<u32, u64> = BTreeMap::new();
        let mut bi_count: BTreeMap<(u32, u32), u64> = BTreeMap::new();
        let mut hist_total: BTreeMap<u32, u64> = BTreeMap::new();
        for sent in corpus {
            let mut h = start;
            for w in sent.iter().chain(std::iter::once(&SENT_END.to_string())) {
                let id = intern(w, &mut vocab, &mut index);
                *uni_count.entry(id).or_default() += 1;
                *bi_count.entry((h, id)).or_default() += 1;
                *hist_total.entry(h).or_default() += 1;
                h = id;
            }
        }
        let _ = end;
        let v = vocab.len();
        // Unigram ML with add-1 smoothing so <unk>/<s> get mass.
        let total_uni: u64 = uni_count.values().sum();
        let mut uni_logp = vec![0.0f32; v];
        for id in 0..v as u32 {
            let c = uni_count.get(&id).copied().unwrap_or(0);
            let p = (c as f64 + 1.0) / (total_uni as f64 + v as f64);
            uni_logp[id as usize] = p.ln() as f32;
        }
        // Bigrams with absolute discounting; leftover mass becomes the
        // backoff weight, normalized against the unigram mass of unseen
        // continuations.
        let mut bi_logp = BTreeMap::new();
        let mut uni_backoff = vec![0.0f32; v];
        for (&h, &ht) in &hist_total {
            let seen: Vec<(u32, u64)> = bi_count
                .range((h, 0)..=(h, u32::MAX))
                .map(|(&(_, w), &c)| (w, c))
                .collect();
            let discounted_mass = discount * seen.len() as f64 / ht as f64;
            let mut seen_uni_mass = 0.0f64;
            for &(w, c) in &seen {
                let p = (c as f64 - discount).max(1e-10) / ht as f64;
                bi_logp.insert((h, w), p.ln() as f32);
                seen_uni_mass += (uni_logp[w as usize] as f64).exp();
            }
            let bo = discounted_mass / (1.0 - seen_uni_mass).max(1e-10);
            uni_backoff[h as usize] = (bo.max(1e-10)).ln() as f32;
        }
        Ok(NgramLm { vocab, index, uni_logp, uni_backoff, bi_logp })
    }

    /// Serialize in ARPA format (log10).
    pub fn to_arpa(&self) -> String {
        let mut out = String::from("\\data\\\n");
        out.push_str(&format!("ngram 1={}\n", self.vocab.len()));
        out.push_str(&format!("ngram 2={}\n\n", self.bi_logp.len()));
        out.push_str("\\1-grams:\n");
        for (id, w) in self.vocab.iter().enumerate() {
            out.push_str(&format!(
                "{:.6}\t{}\t{:.6}\n",
                self.uni_logp[id] as f64 / LN10,
                w,
                self.uni_backoff[id] as f64 / LN10,
            ));
        }
        out.push_str("\n\\2-grams:\n");
        for (&(h, w), &lp) in &self.bi_logp {
            out.push_str(&format!(
                "{:.6}\t{} {}\n",
                lp as f64 / LN10,
                self.vocab[h as usize],
                self.vocab[w as usize]
            ));
        }
        out.push_str("\n\\end\\\n");
        out
    }

    /// Parse ARPA text (orders 1–2; higher orders rejected).
    pub fn from_arpa(text: &str) -> Result<Self> {
        enum Sect {
            None,
            Uni,
            Bi,
        }
        let mut sect = Sect::None;
        let mut vocab = Vec::new();
        let mut index = BTreeMap::new();
        let mut uni: Vec<(f32, f32)> = Vec::new();
        let mut bi_raw: Vec<(String, String, f32)> = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line == "\\data\\" || line.starts_with("ngram ") {
                continue;
            }
            match line {
                "\\1-grams:" => {
                    sect = Sect::Uni;
                    continue;
                }
                "\\2-grams:" => {
                    sect = Sect::Bi;
                    continue;
                }
                "\\end\\" => break,
                l if l.starts_with('\\') => bail!("unsupported ARPA section '{l}' (order > 2?)"),
                _ => {}
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            match sect {
                Sect::Uni => {
                    let (lp, w) = (fields[0], fields[1]);
                    let bo = fields.get(2).copied().unwrap_or("0");
                    let id = vocab.len() as u32;
                    index.insert(w.to_string(), id);
                    vocab.push(w.to_string());
                    uni.push((
                        (lp.parse::<f64>().context("bad unigram logp")? * LN10) as f32,
                        (bo.parse::<f64>().context("bad backoff")? * LN10) as f32,
                    ));
                }
                Sect::Bi => {
                    if fields.len() != 3 {
                        bail!("bad bigram line '{line}'");
                    }
                    bi_raw.push((
                        fields[1].to_string(),
                        fields[2].to_string(),
                        (fields[0].parse::<f64>().context("bad bigram logp")? * LN10) as f32,
                    ));
                }
                Sect::None => bail!("ARPA content before any section: '{line}'"),
            }
        }
        let mut bi_logp = BTreeMap::new();
        for (h, w, lp) in bi_raw {
            let hid = *index.get(&h).with_context(|| format!("bigram history '{h}' not in unigrams"))?;
            let wid = *index.get(&w).with_context(|| format!("bigram word '{w}' not in unigrams"))?;
            bi_logp.insert((hid, wid), lp);
        }
        for special in [SENT_START, SENT_END, UNK] {
            anyhow::ensure!(index.contains_key(special), "ARPA missing {special}");
        }
        let (uni_logp, uni_backoff) = uni.into_iter().unzip();
        Ok(NgramLm { vocab, index, uni_logp, uni_backoff, bi_logp })
    }

    /// Estimated external-memory footprint of the LM graph (simulator).
    pub fn graph_bytes(&self) -> usize {
        self.vocab.len() * 16 + self.bi_logp.len() * 12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Vec<Vec<String>> {
        let sents = [
            "the cat sat",
            "the cat ran",
            "the dog sat",
            "a dog ran",
            "the cat sat here",
        ];
        sents
            .iter()
            .map(|s| s.split_whitespace().map(str::to_string).collect())
            .collect()
    }

    #[test]
    fn seen_bigrams_beat_backoff() {
        let lm = NgramLm::estimate(&corpus(), 0.4).unwrap();
        let the = lm.word_id("the").unwrap();
        let cat = lm.word_id("cat").unwrap();
        let dog = lm.word_id("dog").unwrap();
        let (p_cat, _) = lm.score(LmState(the), cat);
        let (p_dog, _) = lm.score(LmState(the), dog);
        // "the cat" (3×) more likely than "the dog" (1×).
        assert!(p_cat > p_dog, "{p_cat} !> {p_dog}");
    }

    #[test]
    fn probabilities_sum_to_at_most_one() {
        let lm = NgramLm::estimate(&corpus(), 0.4).unwrap();
        // For each history, Σ_w p(w|h) should be ≈ ≤ 1 (backoff approx).
        for h in 0..lm.vocab_len() as u32 {
            let total: f64 = (0..lm.vocab_len() as u32)
                .map(|w| (lm.score(LmState(h), w).0 as f64).exp())
                .sum();
            assert!(total < 1.35, "history {h}: Σp = {total}");
        }
    }

    #[test]
    fn likely_sentence_scores_higher() {
        let lm = NgramLm::estimate(&corpus(), 0.4).unwrap();
        let likely = lm.sentence_logp(&["the", "cat", "sat"]);
        let unlikely = lm.sentence_logp(&["here", "a", "the"]);
        assert!(likely > unlikely);
    }

    #[test]
    fn unknown_words_fall_back_to_unk() {
        let lm = NgramLm::estimate(&corpus(), 0.4).unwrap();
        let lp = lm.sentence_logp(&["zebra"]);
        assert!(lp.is_finite());
    }

    #[test]
    fn arpa_roundtrip() {
        let lm = NgramLm::estimate(&corpus(), 0.4).unwrap();
        let text = lm.to_arpa();
        let re = NgramLm::from_arpa(&text).unwrap();
        assert_eq!(re.vocab_len(), lm.vocab_len());
        // Scores survive the log10 roundtrip.
        let the = lm.word_id("the").unwrap();
        let cat = lm.word_id("cat").unwrap();
        let a = lm.score(LmState(the), cat).0;
        let b = re.score(
            LmState(re.word_id("the").unwrap()),
            re.word_id("cat").unwrap(),
        )
        .0;
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }

    #[test]
    fn arpa_rejects_malformed() {
        assert!(NgramLm::from_arpa("\\3-grams:\n").is_err());
        assert!(NgramLm::from_arpa("0.5 stray line").is_err());
        // Missing specials.
        assert!(NgramLm::from_arpa("\\1-grams:\n-1.0\tfoo\t0\n\\end\\\n").is_err());
    }

    #[test]
    fn estimate_rejects_bad_args() {
        assert!(NgramLm::estimate(&[], 0.4).is_err());
        assert!(NgramLm::estimate(&corpus(), 1.5).is_err());
    }
}
