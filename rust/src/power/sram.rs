//! CACTI-like SRAM macro model at the 32 nm node.
//!
//! The paper (§5.1) uses CACTI for all memories: area, leakage power and
//! per-access energy. We implement the same interface as a calibrated
//! analytical model — linear area and leakage in capacity (high-Vt cells)
//! plus a periphery constant, and access energy growing with √capacity
//! (bitline/wordline lengths). Constants are calibrated so the full-chip
//! budget reproduces the paper's Fig. 10 totals (see `power::tests`).

/// Calibrated 32 nm constants.
pub mod k32 {
    /// Cell-array area per KB (mm²) including array periphery.
    pub const AREA_MM2_PER_KB: f64 = 0.0020;
    /// Fixed macro overhead (decoders, sense amps) per instance (mm²).
    pub const AREA_MACRO_MM2: f64 = 0.02;
    /// Cache overhead (tags, replacement state, control) multiplier for
    /// small caches; large caches amortize tags over longer lines.
    pub const CACHE_OVERHEAD_SMALL: f64 = 1.35;
    pub const CACHE_OVERHEAD_LARGE: f64 = 1.18;
    /// Boundary between the two (KB).
    pub const CACHE_LARGE_KB: f64 = 256.0;
    /// Leakage per KB (W), high-Vt (Saed32hvt-class) cells.
    pub const LEAK_W_PER_KB: f64 = 0.18e-3;
    /// Access energy: `E = E0 · √KB` (J/access).
    pub const ACCESS_J_SQRT_KB: f64 = 3.5e-12;
}

/// Kind of memory macro (affects overhead factor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacroKind {
    /// Software-managed scratchpad or plain SRAM.
    Scratchpad,
    /// Hardware-managed cache (tag/control overhead).
    Cache,
}

/// One SRAM macro.
#[derive(Debug, Clone, Copy)]
pub struct SramMacro {
    pub bytes: usize,
    pub ports: usize,
    pub kind: MacroKind,
}

impl SramMacro {
    pub fn new(bytes: usize, ports: usize, kind: MacroKind) -> Self {
        assert!(bytes > 0 && ports > 0);
        SramMacro { bytes, ports, kind }
    }

    fn kb(&self) -> f64 {
        self.bytes as f64 / 1024.0
    }

    fn overhead(&self) -> f64 {
        match self.kind {
            MacroKind::Scratchpad => 1.0,
            MacroKind::Cache if self.kb() >= k32::CACHE_LARGE_KB => k32::CACHE_OVERHEAD_LARGE,
            MacroKind::Cache => k32::CACHE_OVERHEAD_SMALL,
        }
    }

    /// Area in mm² (multi-port cells grow ~30% per extra port).
    pub fn area_mm2(&self) -> f64 {
        let port_factor = 1.0 + 0.3 * (self.ports - 1) as f64;
        (k32::AREA_MM2_PER_KB * self.kb() * port_factor + k32::AREA_MACRO_MM2) * self.overhead()
    }

    /// Leakage power in W.
    pub fn leakage_w(&self) -> f64 {
        k32::LEAK_W_PER_KB * self.kb() * self.overhead()
    }

    /// Energy per access in J.
    pub fn access_energy_j(&self) -> f64 {
        k32::ACCESS_J_SQRT_KB * self.kb().sqrt() * self.overhead()
    }

    /// Peak dynamic power at `freq` Hz — the §5.3 methodology: "we assume
    /// as peak power the scenario where all the ports are accessed once
    /// per cycle".
    pub fn peak_dynamic_w(&self, freq: f64) -> f64 {
        self.access_energy_j() * self.ports as f64 * freq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_size() {
        let small = SramMacro::new(4 << 10, 1, MacroKind::Scratchpad);
        let big = SramMacro::new(1 << 20, 1, MacroKind::Scratchpad);
        assert!(big.area_mm2() > small.area_mm2());
        assert!(big.leakage_w() > small.leakage_w());
        assert!(big.access_energy_j() > small.access_energy_j());
        // Access energy grows sublinearly (√).
        let ratio = big.access_energy_j() / small.access_energy_j();
        assert!(ratio < 20.0, "access energy ratio {ratio} not sublinear");
    }

    #[test]
    fn cache_overhead_applies() {
        let s = SramMacro::new(24 << 10, 1, MacroKind::Scratchpad);
        let c = SramMacro::new(24 << 10, 1, MacroKind::Cache);
        assert!(c.area_mm2() > s.area_mm2());
        assert!((c.leakage_w() / s.leakage_w() - k32::CACHE_OVERHEAD_SMALL).abs() < 1e-9);
        // Large caches amortize tag overhead.
        let big = SramMacro::new(1 << 20, 1, MacroKind::Cache);
        let big_s = SramMacro::new(1 << 20, 1, MacroKind::Scratchpad);
        assert!((big.leakage_w() / big_s.leakage_w() - k32::CACHE_OVERHEAD_LARGE).abs() < 1e-9);
    }

    #[test]
    fn paper_scale_magnitudes() {
        // 1 MB model memory at 32 nm: ~2.5 mm², fraction-of-mW-per-KB
        // leakage, tens of pJ per access — CACTI-like magnitudes.
        let m = SramMacro::new(1 << 20, 1, MacroKind::Scratchpad);
        assert!((1.8..3.2).contains(&m.area_mm2()), "{}", m.area_mm2());
        let leak_uw_per_kb = m.leakage_w() / 1024.0 * 1e6;
        assert!((100.0..300.0).contains(&leak_uw_per_kb), "leak {leak_uw_per_kb} µW/KB");
        let pj = m.access_energy_j() * 1e12;
        assert!((50.0..200.0).contains(&pj), "access energy {pj} pJ");
    }

    #[test]
    fn multi_port_costs_area_and_power() {
        let p1 = SramMacro::new(64 << 10, 1, MacroKind::Scratchpad);
        let p2 = SramMacro::new(64 << 10, 2, MacroKind::Scratchpad);
        assert!(p2.area_mm2() > p1.area_mm2());
        assert!(p2.peak_dynamic_w(5e8) > 1.9 * p1.peak_dynamic_w(5e8));
    }
}
