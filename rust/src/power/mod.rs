//! Area and power estimation (§5.3, Fig. 10): CACTI-like memories
//! ([`sram`]), McPAT-like PE cores and Design-Compiler-like special
//! function units, all at the 32 nm node, composed into the full-chip
//! budget with the paper's peak-power methodology:
//!
//! > "Peak power is estimated by adding together the leakage power and
//! > peak dynamic power for the logic units … In the case of memories we
//! > assume as peak power the scenario where all the ports are accessed
//! > once per cycle."

pub mod sram;

use crate::accel::StepReport;
use crate::config::AccelConfig;
use sram::{MacroKind, SramMacro};

/// McPAT-like PE-core constants at 32 nm (in-order RISC-V with FP ALU,
/// 8-lane int8 vector MAC and log/exp/cos SFUs, §3.4).
pub mod core32 {
    /// Core area including register files, vector unit and SFUs (mm²).
    pub const PE_AREA_MM2: f64 = 0.775;
    /// Core leakage (W).
    pub const PE_LEAK_W: f64 = 55e-3;
    /// Average energy per executed instruction (J) — dominates peak
    /// dynamic power ("the rest comes from dynamic power, mainly from
    /// the PE cores", §5.3).
    pub const PE_ENERGY_PER_INSTR_J: f64 = 165e-12;
    /// PE interconnect bus (§3.4: PE↔memories + PE↔controller buses).
    pub const BUS_AREA_MM2: f64 = 0.35;
    pub const BUS_LEAK_W: f64 = 10e-3;
    pub const BUS_PEAK_DYN_W: f64 = 50e-3;
    /// ASR controller + command decoder logic.
    pub const CTRL_AREA_MM2: f64 = 0.08;
    pub const CTRL_LEAK_W: f64 = 5e-3;
    pub const CTRL_PEAK_DYN_W: f64 = 8e-3;
    /// Hypothesis-unit controller (sort/prune logic, §3.5).
    pub const HYP_CTRL_AREA_MM2: f64 = 0.03;
    pub const HYP_CTRL_LEAK_W: f64 = 2e-3;
    pub const HYP_CTRL_PEAK_DYN_W: f64 = 5e-3;
    /// External-memory (LPDDR4-class) energy per byte transferred (J/B),
    /// used for per-step energy, not chip peak power.
    pub const EXT_MEM_J_PER_BYTE: f64 = 15e-12;
}

/// One row of the Fig. 10 component breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct ComponentBudget {
    pub name: String,
    pub area_mm2: f64,
    pub leakage_w: f64,
    pub peak_dynamic_w: f64,
}

impl ComponentBudget {
    pub fn peak_w(&self) -> f64 {
        self.leakage_w + self.peak_dynamic_w
    }
}

/// Full-chip budget.
#[derive(Debug, Clone)]
pub struct ChipBudget {
    pub components: Vec<ComponentBudget>,
}

impl ChipBudget {
    /// Build the budget for an accelerator configuration.
    pub fn for_config(accel: &AccelConfig) -> Self {
        let freq = accel.frequency_hz as f64;
        let n = accel.num_pes as f64;
        let mut components = Vec::new();

        // Execution unit: PE cores.
        components.push(ComponentBudget {
            name: "PE cores".into(),
            area_mm2: core32::PE_AREA_MM2 * n,
            leakage_w: core32::PE_LEAK_W * n,
            peak_dynamic_w: core32::PE_ENERGY_PER_INSTR_J * freq * n,
        });
        // Per-PE caches.
        let pe_d = SramMacro::new(accel.pe_dcache_bytes, 1, MacroKind::Cache);
        let pe_i = SramMacro::new(accel.pe_icache_bytes, 1, MacroKind::Cache);
        components.push(ComponentBudget {
            name: "PE d-caches".into(),
            area_mm2: pe_d.area_mm2() * n,
            leakage_w: pe_d.leakage_w() * n,
            peak_dynamic_w: pe_d.peak_dynamic_w(freq) * n,
        });
        components.push(ComponentBudget {
            name: "PE i-caches".into(),
            area_mm2: pe_i.area_mm2() * n,
            leakage_w: pe_i.leakage_w() * n,
            peak_dynamic_w: pe_i.peak_dynamic_w(freq) * n,
        });
        // PE bus.
        components.push(ComponentBudget {
            name: "PE bus".into(),
            area_mm2: core32::BUS_AREA_MM2,
            leakage_w: core32::BUS_LEAK_W,
            peak_dynamic_w: core32::BUS_PEAK_DYN_W,
        });
        // Shared memories.
        let shared = SramMacro::new(accel.shared_mem_bytes, 2, MacroKind::Scratchpad);
        components.push(ComponentBudget {
            name: "Shared memory".into(),
            area_mm2: shared.area_mm2(),
            leakage_w: shared.leakage_w(),
            peak_dynamic_w: shared.peak_dynamic_w(freq),
        });
        let model = SramMacro::new(accel.model_mem_bytes, 1, MacroKind::Cache);
        components.push(ComponentBudget {
            name: "Model memory / d-cache".into(),
            area_mm2: model.area_mm2(),
            leakage_w: model.leakage_w(),
            peak_dynamic_w: model.peak_dynamic_w(freq),
        });
        let icache = SramMacro::new(accel.shared_icache_bytes, 1, MacroKind::Cache);
        components.push(ComponentBudget {
            name: "Shared i-cache".into(),
            area_mm2: icache.area_mm2(),
            leakage_w: icache.leakage_w(),
            peak_dynamic_w: icache.peak_dynamic_w(freq),
        });
        // Hypothesis unit: memory + sort/prune controller.
        let hyp = SramMacro::new(accel.hyp_mem_bytes, 1, MacroKind::Scratchpad);
        components.push(ComponentBudget {
            name: "Hypothesis unit".into(),
            area_mm2: hyp.area_mm2() + core32::HYP_CTRL_AREA_MM2,
            leakage_w: hyp.leakage_w() + core32::HYP_CTRL_LEAK_W,
            peak_dynamic_w: hyp.peak_dynamic_w(freq) + core32::HYP_CTRL_PEAK_DYN_W,
        });
        // ASR controller + command decoder.
        components.push(ComponentBudget {
            name: "Controller".into(),
            area_mm2: core32::CTRL_AREA_MM2,
            leakage_w: core32::CTRL_LEAK_W,
            peak_dynamic_w: core32::CTRL_PEAK_DYN_W,
        });
        ChipBudget { components }
    }

    pub fn total_area_mm2(&self) -> f64 {
        self.components.iter().map(|c| c.area_mm2).sum()
    }

    pub fn total_leakage_w(&self) -> f64 {
        self.components.iter().map(|c| c.leakage_w).sum()
    }

    pub fn total_peak_dynamic_w(&self) -> f64 {
        self.components.iter().map(|c| c.peak_dynamic_w).sum()
    }

    pub fn total_peak_w(&self) -> f64 {
        self.total_leakage_w() + self.total_peak_dynamic_w()
    }

    /// Area share of the execution unit (PEs + PE caches + PE bus) — the
    /// paper reports 65%.
    pub fn execution_unit_share(&self) -> f64 {
        let exec: f64 = self
            .components
            .iter()
            .filter(|c| c.name.starts_with("PE"))
            .map(|c| c.area_mm2)
            .sum();
        exec / self.total_area_mm2()
    }

    /// Area share of the shared + model memories — the paper reports 32%.
    pub fn memories_share(&self) -> f64 {
        let mem: f64 = self
            .components
            .iter()
            .filter(|c| c.name.starts_with("Shared memory") || c.name.starts_with("Model"))
            .map(|c| c.area_mm2)
            .sum();
        mem / self.total_area_mm2()
    }

    pub fn component(&self, name: &str) -> &ComponentBudget {
        self.components
            .iter()
            .find(|c| c.name == name)
            .unwrap_or_else(|| panic!("no component '{name}'"))
    }
}

/// Energy of one simulated decoding step (average power = energy / time):
/// instruction energy + memory access energy approximated from traffic +
/// external-memory transfer energy + leakage over the step duration.
pub fn step_energy_j(report: &StepReport, accel: &AccelConfig) -> f64 {
    let budget = ChipBudget::for_config(accel);
    let shared = SramMacro::new(accel.shared_mem_bytes, 2, MacroKind::Scratchpad);
    let model = SramMacro::new(accel.model_mem_bytes, 1, MacroKind::Cache);
    let instr_e = report.total_instrs as f64 * core32::PE_ENERGY_PER_INSTR_J;
    // Shared-memory traffic: one access per 8 bytes (64-bit port).
    let smem_bytes: u64 = report.kernels.iter().map(|k| k.instrs / 2).sum::<u64>().min(u64::MAX);
    let _ = smem_bytes;
    let smem_accesses: f64 = report
        .kernels
        .iter()
        .map(|k| k.instrs as f64 * 0.4) // ~40% of instructions touch memory
        .sum();
    let mem_e = smem_accesses * 0.5 * (shared.access_energy_j() + model.access_energy_j());
    let dma_e = report.dma_bytes as f64 * core32::EXT_MEM_J_PER_BYTE;
    let leak_e = budget.total_leakage_w() * report.total_cycles as f64 / accel.frequency_hz as f64;
    instr_e + mem_e + dma_e + leak_e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{simulate_step, HypWorkload, SimMode};
    use crate::config::ModelConfig;

    #[test]
    fn int8_weights_cut_dma_traffic_and_step_energy() {
        // The Precision knob must show up in the power model: the f32
        // variant streams ≈4× the weight bytes (LayerNorm params are f32
        // in both), so its per-step DMA energy — and total energy — is
        // strictly higher.
        let accel = AccelConfig::paper();
        let m8 = ModelConfig::paper_tds();
        let m32 = ModelConfig {
            precision: crate::config::Precision::F32,
            ..ModelConfig::paper_tds()
        };
        let r8 = simulate_step(&m8, &accel, &HypWorkload::default(), SimMode::Ideal);
        let r32 = simulate_step(&m32, &accel, &HypWorkload::default(), SimMode::Ideal);
        assert!(
            r32.dma_bytes > 3 * r8.dma_bytes,
            "f32 dma {} !≫ int8 dma {}",
            r32.dma_bytes,
            r8.dma_bytes
        );
        assert!(step_energy_j(&r32, &accel) > step_energy_j(&r8, &accel));
    }

    #[test]
    fn int4_weights_cut_dma_traffic_and_step_energy_below_int8() {
        // Sub-int8 formats must keep paying off in the power model: the
        // packed-int4 step streams strictly fewer weight bytes than the
        // int8 paper config (and 2:4 sparse fewer still), so per-step
        // DMA energy — and total energy — keeps falling.
        use crate::accel::simulate_pipeline;
        use crate::config::{PipelineDesc, Precision, PrecisionMap};
        let accel = AccelConfig::paper();
        let m = ModelConfig::paper_tds();
        let hyp = HypWorkload::default();
        let step = |p: Precision| {
            let pipe = PipelineDesc::for_model_mixed(&m, PrecisionMap::uniform(p));
            simulate_pipeline(&pipe, &accel, &hyp, SimMode::Ideal, 1)
        };
        let r8 = step(Precision::Int8);
        let r4 = step(Precision::Int4);
        let rs = step(Precision::Int4Sparse);
        assert!(r4.dma_bytes < r8.dma_bytes, "int4 {} !< int8 {}", r4.dma_bytes, r8.dma_bytes);
        assert!(rs.dma_bytes < r4.dma_bytes, "sparse {} !< int4 {}", rs.dma_bytes, r4.dma_bytes);
        assert!(step_energy_j(&r4, &accel) < step_energy_j(&r8, &accel));
        assert!(step_energy_j(&rs, &accel) < step_energy_j(&r4, &accel));
    }

    #[test]
    fn total_area_matches_paper() {
        // §5.3: "the total area is 11.68 mm²".
        let b = ChipBudget::for_config(&AccelConfig::paper());
        let a = b.total_area_mm2();
        assert!((11.68 - a).abs() / 11.68 < 0.08, "area {a:.2} mm² vs 11.68");
    }

    #[test]
    fn area_shares_match_paper() {
        // §5.3: execution unit 65%, shared+model memories 32%,
        // hypothesis unit < 1%.
        let b = ChipBudget::for_config(&AccelConfig::paper());
        let exec = b.execution_unit_share();
        let mem = b.memories_share();
        assert!((exec - 0.65).abs() < 0.05, "execution unit share {exec:.3}");
        assert!((mem - 0.32).abs() < 0.05, "memories share {mem:.3}");
        let hyp = b.component("Hypothesis unit").area_mm2 / b.total_area_mm2();
        assert!(hyp < 0.012, "hypothesis unit share {hyp:.4}");
    }

    #[test]
    fn peak_power_matches_paper() {
        // §5.3: "slightly more than 1.8 W assuming peak power. Around
        // 800 mW come from static power".
        let b = ChipBudget::for_config(&AccelConfig::paper());
        let peak = b.total_peak_w();
        let leak = b.total_leakage_w();
        assert!((1.65..2.05).contains(&peak), "peak {peak:.3} W vs ≈1.8+");
        assert!((0.70..0.90).contains(&leak), "static {leak:.3} W vs ≈0.8");
    }

    #[test]
    fn static_power_dominated_by_cores_and_big_memories() {
        // §5.3: "mostly from the PE cores and the shared and model
        // memories".
        let b = ChipBudget::for_config(&AccelConfig::paper());
        let cores = b.component("PE cores").leakage_w;
        let mems = b.component("Shared memory").leakage_w
            + b.component("Model memory / d-cache").leakage_w;
        assert!((cores + mems) / b.total_leakage_w() > 0.75);
    }

    #[test]
    fn dynamic_power_mainly_pe_cores() {
        let b = ChipBudget::for_config(&AccelConfig::paper());
        let cores = b.component("PE cores").peak_dynamic_w;
        assert!(cores / b.total_peak_dynamic_w() > 0.6);
    }

    #[test]
    fn budget_scales_with_pes() {
        let mut cfg = AccelConfig::paper();
        let base = ChipBudget::for_config(&cfg).total_area_mm2();
        cfg.num_pes = 16;
        let doubled = ChipBudget::for_config(&cfg).total_area_mm2();
        assert!(doubled > base * 1.4);
    }

    #[test]
    fn step_energy_is_sane() {
        // Average power during a decoding step must be below chip peak
        // and above leakage alone.
        let accel = AccelConfig::paper();
        let model = ModelConfig::paper_tds();
        let r = simulate_step(&model, &accel, &HypWorkload::default(), SimMode::Ideal);
        let e = step_energy_j(&r, &accel);
        let seconds = r.seconds(&accel);
        let avg_w = e / seconds;
        let b = ChipBudget::for_config(&accel);
        assert!(avg_w < b.total_peak_w(), "avg {avg_w:.3} W above peak");
        assert!(avg_w > b.total_leakage_w(), "avg {avg_w:.3} W below leakage");
        // Energy per second of decoded audio, order of 10s of mJ–1 J.
        let e_per_audio_s = e / model.step_seconds();
        assert!((0.01..3.0).contains(&e_per_audio_s), "{e_per_audio_s} J/s");
    }
}
