//! The shared stage/program description of one decoding step — the
//! paper's "one program per decoder part" made explicit as data.
//!
//! ASRPU's programmability story (§3, §4) is that the decoding step is an
//! ordered sequence of small programs: feature extraction, one kernel per
//! acoustic-model layer, then a hypothesis-expansion program per acoustic
//! vector. This module is the single source of truth for that sequence.
//! Both halves of the repo consume it:
//!
//! * the **functional engine** ([`crate::coordinator::Engine::pipeline`])
//!   executes exactly this stage list per step, and
//! * the **cycle-approximate simulator**
//!   ([`crate::accel::build_step_kernels`]) derives its kernel program —
//!   instruction counts, threads, model-memory staging — from the same
//!   description,
//!
//! so a new workload (a different model topology, a greedy path with no
//! hypothesis expansion, keyword spotting over a trimmed stage list)
//! changes one description instead of two hand-maintained programs.
#![deny(missing_docs)]

use super::model::{Layer, ModelConfig, PrecisionMap};
use crate::am::gemm::dispatch::KernelIsa;

/// One stage of the decoding-step pipeline, in execution order.
#[derive(Debug, Clone, PartialEq)]
pub enum StageDesc {
    /// The MFCC front-end: `samples_per_step` audio samples in,
    /// `frames_per_step × n_mels` feature frames out (one thread per
    /// output frame on the accelerator).
    Features,
    /// One acoustic-model layer program (§4.2: one kernel per layer, one
    /// thread per output neuron).
    AmLayer(Layer),
    /// The hypothesis-expansion program, run once per acoustic score
    /// vector (Fig. 6) — `repeats` executions per decoding step.
    HypExpansion {
        /// Executions per decoding step (`vectors_per_step`).
        repeats: usize,
    },
    /// Second-pass LM rescoring of the exact N-best list at utterance
    /// finish (`decoder::rescore`) — present only when the engine is
    /// configured with a rescorer ([`EngineBuilder::rescore`]).
    ///
    /// [`EngineBuilder::rescore`]: crate::coordinator::EngineBuilder::rescore
    Rescore {
        /// N-best paths extracted from the lattice and re-ranked.
        nbest: usize,
    },
}

impl StageDesc {
    /// Short human-readable stage name (kernel naming, introspection).
    pub fn name(&self) -> String {
        match self {
            StageDesc::Features => "feat.mfcc".to_string(),
            StageDesc::AmLayer(layer) => layer.name().to_string(),
            StageDesc::HypExpansion { repeats } => format!("hyp.expand×{repeats}"),
            StageDesc::Rescore { nbest } => format!("lm.rescore×{nbest}"),
        }
    }
}

/// The complete program description of one decoding step for a model:
/// the model geometry plus the ordered stage list derived from it.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineDesc {
    /// The model geometry every stage is shaped by.
    pub model: ModelConfig,
    /// Stages in execution order: features, the AM layers, hypothesis
    /// expansion.
    pub stages: Vec<StageDesc>,
    /// The host AM kernel ISA active when this description was built —
    /// what [`crate::am::gemm::dispatch::active`] resolved to (runtime
    /// detection, `ASRPU_KERNEL_ISA`, or a thread-local force). Purely
    /// throughput accounting: kernels are bit-identical across ISAs, so
    /// the stage list and every result are unaffected.
    pub host_isa: KernelIsa,
    /// Per-layer weight-precision assignment the AM stages execute at.
    /// [`PipelineDesc::for_model`] sets it uniform at the model's scalar
    /// precision; a mixed-precision backend overrides it with its
    /// calibrated map so the simulator sizes each layer's weight DMA from
    /// what the engine actually stores.
    pub precisions: PrecisionMap,
}

impl PipelineDesc {
    /// The canonical decoding-step pipeline for a model: MFCC features,
    /// every AM layer in execution order, then one hypothesis expansion
    /// per acoustic vector.
    pub fn for_model(model: &ModelConfig) -> Self {
        let mut stages = Vec::with_capacity(model.layers().len() + 2);
        stages.push(StageDesc::Features);
        for layer in model.layers() {
            stages.push(StageDesc::AmLayer(layer));
        }
        stages.push(StageDesc::HypExpansion { repeats: model.vectors_per_step() });
        PipelineDesc {
            model: model.clone(),
            stages,
            host_isa: KernelIsa::active(),
            precisions: PrecisionMap::uniform(model.precision),
        }
    }

    /// The canonical pipeline with a calibrated per-layer precision map
    /// in place of the model's uniform scalar precision.
    pub fn for_model_mixed(model: &ModelConfig, precisions: PrecisionMap) -> Self {
        PipelineDesc { precisions, ..Self::for_model(model) }
    }

    /// Number of acoustic-model layer stages.
    pub fn am_stage_count(&self) -> usize {
        self.stages
            .iter()
            .filter(|s| matches!(s, StageDesc::AmLayer(_)))
            .count()
    }

    /// Total hypothesis-expansion executions per decoding step.
    pub fn hyp_repeats(&self) -> usize {
        self.stages
            .iter()
            .map(|s| match s {
                StageDesc::HypExpansion { repeats } => *repeats,
                _ => 0,
            })
            .sum()
    }

    /// Multiply-accumulates one decoding step executes across the AM
    /// stages, per lane: each layer's per-timestep MACs times the number
    /// of timesteps it produces in a step (`frames_per_step` divided by
    /// the strides accumulated so far). This is the numerator the host
    /// kernel benches use for GMAC/s, and the same MAC count the
    /// simulator's per-layer kernel programs are sized from.
    pub fn macs_per_step(&self) -> u64 {
        let mut t = self.model.frames_per_step();
        let mut macs = 0u64;
        for stage in &self.stages {
            if let StageDesc::AmLayer(layer) = stage {
                if let Layer::Conv { stride, .. } = layer {
                    t /= *stride;
                }
                macs += layer.macs_per_timestep() as u64 * t as u64;
            }
        }
        macs
    }

    /// Validate internal consistency: AM stages must chain dimensionally
    /// from `n_mels` to `tokens` exactly like the model's layer list.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut cur = self.model.n_mels;
        for stage in &self.stages {
            if let StageDesc::AmLayer(layer) = stage {
                match layer {
                    Layer::Conv { in_ch, out_ch, w, .. } => {
                        anyhow::ensure!(
                            cur == in_ch * w,
                            "stage {}: expects {} inputs, pipeline carries {cur}",
                            layer.name(),
                            in_ch * w
                        );
                        cur = out_ch * w;
                    }
                    Layer::Fc { in_dim, out_dim, .. } => {
                        anyhow::ensure!(
                            cur == *in_dim,
                            "stage {}: expects {in_dim} inputs, pipeline carries {cur}",
                            layer.name()
                        );
                        cur = *out_dim;
                    }
                    Layer::LayerNorm { dim, .. } => {
                        anyhow::ensure!(
                            cur == *dim,
                            "stage {}: expects {dim} inputs, pipeline carries {cur}",
                            layer.name()
                        );
                    }
                }
            }
        }
        anyhow::ensure!(
            cur == self.model.tokens,
            "pipeline emits {cur} values per vector, model expects {} tokens",
            self.model.tokens
        );
        self.precisions
            .validate(&self.model)
            .map_err(|e| anyhow::anyhow!("pipeline precision map: {e}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_pipeline_shape() {
        let m = ModelConfig::paper_tds();
        let p = PipelineDesc::for_model(&m);
        // features + 79 AM kernels + hyp expansion.
        assert_eq!(p.stages.len(), 1 + 79 + 1);
        assert_eq!(p.am_stage_count(), 79);
        assert_eq!(p.hyp_repeats(), m.vectors_per_step());
        assert_eq!(p.stages[0], StageDesc::Features);
        assert!(matches!(p.stages.last(), Some(StageDesc::HypExpansion { repeats: 4 })));
        p.validate().unwrap();
    }

    #[test]
    fn validation_rejects_broken_chains() {
        let m = ModelConfig::tiny_tds();
        let mut p = PipelineDesc::for_model(&m);
        p.validate().unwrap();
        // Drop one AM stage: the dimension chain breaks.
        let idx = p
            .stages
            .iter()
            .position(|s| matches!(s, StageDesc::AmLayer(Layer::Conv { .. })))
            .unwrap();
        p.stages.remove(idx);
        assert!(p.validate().is_err());
    }

    #[test]
    fn macs_per_step_counts_strided_timesteps() {
        // Paper model: the conv/FC MAC volume of one 80 ms step sits in
        // the tens-to-hundreds of millions (the §5.1 instruction counts
        // are ~0.75 instruction slots per MAC at vector width 8).
        let paper = PipelineDesc::for_model(&ModelConfig::paper_tds());
        let macs = paper.macs_per_step();
        assert!(
            (20_000_000..800_000_000).contains(&macs),
            "paper MACs/step out of band: {macs}"
        );
        let tiny = PipelineDesc::for_model(&ModelConfig::tiny_tds());
        assert!(tiny.macs_per_step() > 0);
        assert!(tiny.macs_per_step() < macs);
    }

    #[test]
    fn host_isa_is_the_dispatch_isa() {
        use crate::am::gemm::dispatch;
        let m = ModelConfig::tiny_tds();
        assert_eq!(PipelineDesc::for_model(&m).host_isa, KernelIsa::active());
        let forced = dispatch::with_forced_isa(KernelIsa::Scalar, || {
            PipelineDesc::for_model(&m).host_isa
        });
        assert_eq!(forced, KernelIsa::Scalar);
    }

    #[test]
    fn stage_names_are_stable() {
        let m = ModelConfig::tiny_tds();
        let p = PipelineDesc::for_model(&m);
        assert_eq!(p.stages[0].name(), "feat.mfcc");
        assert_eq!(p.stages[1].name(), "g0.sub");
        assert_eq!(p.stages.last().unwrap().name(), "hyp.expand×4");
        assert_eq!(StageDesc::Rescore { nbest: 8 }.name(), "lm.rescore×8");
    }

    #[test]
    fn pipeline_carries_precision_map() {
        use crate::config::{Precision, PrecisionMap};
        let m = ModelConfig::paper_tds();
        let p = PipelineDesc::for_model(&m);
        assert_eq!(p.precisions, PrecisionMap::uniform(Precision::Int8));
        let mut map = PrecisionMap::uniform(Precision::Int4);
        map.set("output.fc", Precision::Int8);
        let mixed = PipelineDesc::for_model_mixed(&m, map.clone());
        assert_eq!(mixed.precisions, map);
        assert_eq!(mixed.stages, p.stages, "the map never changes the stage list");
        mixed.validate().unwrap();
        // An override naming a nonexistent layer fails validation.
        let mut bad = PrecisionMap::uniform(Precision::Int4);
        bad.set("nope", Precision::Int8);
        assert!(PipelineDesc::for_model_mixed(&m, bad).validate().is_err());
    }

    #[test]
    fn rescore_stage_is_append_only() {
        // The canonical pipeline never contains a rescore stage — it is
        // appended by the engine only when a rescorer is configured —
        // and appending one keeps the description valid (it neither
        // consumes nor produces activations in the AM chain).
        let m = ModelConfig::tiny_tds();
        let mut p = PipelineDesc::for_model(&m);
        assert!(!p.stages.iter().any(|s| matches!(s, StageDesc::Rescore { .. })));
        p.stages.push(StageDesc::Rescore { nbest: 4 });
        p.validate().unwrap();
        assert_eq!(p.am_stage_count(), PipelineDesc::for_model(&m).am_stage_count());
    }
}
