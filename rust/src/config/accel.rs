//! Accelerator configuration — the paper's Table 2.
//!
//! The preset [`AccelConfig::paper`] is the exact configuration evaluated
//! in §5 (500 MHz, 8 PEs, 8-wide int8 vector MAC, 24 KB hypothesis memory,
//! 64 KB shared I-cache, 512 KB shared scratchpad, 1 MB model memory /
//! D-cache, per-PE 4 KB I$ / 24 KB D$). Sweep examples mutate copies of it.

/// Hardware parameters of one ASRPU instance (Table 2).
#[derive(Debug, Clone, PartialEq)]
pub struct AccelConfig {
    /// Core clock in Hz (paper: 500 MHz).
    pub frequency_hz: u64,
    /// Number of processing elements in the pool (paper: 8).
    pub num_pes: usize,
    /// Vector MAC width in 8-bit lanes (paper: 8).
    pub mac_vector_width: usize,
    /// Hypothesis memory inside the hypothesis unit, bytes (paper: 24 KB).
    pub hyp_mem_bytes: usize,
    /// Shared instruction cache, bytes (paper: 64 KB).
    pub shared_icache_bytes: usize,
    /// Shared scratchpad ("Shared Memory"), bytes (paper: 512 KB).
    pub shared_mem_bytes: usize,
    /// Model memory / shared D-cache, bytes (paper: 1 MB).
    pub model_mem_bytes: usize,
    /// Per-PE instruction cache, bytes (paper: 4 KB).
    pub pe_icache_bytes: usize,
    /// Per-PE data cache, bytes (paper: 24 KB).
    pub pe_dcache_bytes: usize,
    /// External-memory (DRAM) bandwidth available to the DMA engine,
    /// bytes/second. Not in Table 2; used to model the DMA prefetch
    /// latency the paper's Fig. 7 hides behind setup threads
    /// (LPDDR4-class edge device: ~8 GB/s).
    pub ext_mem_bw_bytes_per_s: u64,
    /// Size of a hypothesis record in hypothesis memory, bytes (hash,
    /// score, backlink, lexicon-node ptr, LM-state ptr, token id — §3.5).
    pub hyp_record_bytes: usize,
}

impl AccelConfig {
    /// Table 2 configuration.
    pub fn paper() -> Self {
        AccelConfig {
            frequency_hz: 500_000_000,
            num_pes: 8,
            mac_vector_width: 8,
            hyp_mem_bytes: 24 << 10,
            shared_icache_bytes: 64 << 10,
            shared_mem_bytes: 512 << 10,
            model_mem_bytes: 1 << 20,
            pe_icache_bytes: 4 << 10,
            pe_dcache_bytes: 24 << 10,
            ext_mem_bw_bytes_per_s: 8_000_000_000,
            hyp_record_bytes: 32,
        }
    }

    /// Maximum number of hypotheses the hypothesis memory can hold. The
    /// memory is split between the incoming (active) and outgoing (newly
    /// generated, pre-prune) sets, hence the /2.
    pub fn hyp_capacity(&self) -> usize {
        self.hyp_mem_bytes / self.hyp_record_bytes / 2
    }

    /// Seconds per core cycle.
    pub fn cycle_s(&self) -> f64 {
        1.0 / self.frequency_hz as f64
    }

    /// Sanity checks used by constructors and property tests.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.frequency_hz > 0, "frequency must be positive");
        anyhow::ensure!(self.num_pes > 0, "need at least one PE");
        anyhow::ensure!(
            self.mac_vector_width.is_power_of_two(),
            "MAC width must be a power of two"
        );
        anyhow::ensure!(self.hyp_capacity() >= 2, "hypothesis memory too small");
        anyhow::ensure!(self.model_mem_bytes >= 64 << 10, "model memory too small");
        Ok(())
    }
}

impl Default for AccelConfig {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_table2() {
        let c = AccelConfig::paper();
        assert_eq!(c.frequency_hz, 500_000_000);
        assert_eq!(c.num_pes, 8);
        assert_eq!(c.mac_vector_width, 8);
        assert_eq!(c.hyp_mem_bytes, 24 * 1024);
        assert_eq!(c.shared_icache_bytes, 64 * 1024);
        assert_eq!(c.shared_mem_bytes, 512 * 1024);
        assert_eq!(c.model_mem_bytes, 1024 * 1024);
        assert_eq!(c.pe_icache_bytes, 4 * 1024);
        assert_eq!(c.pe_dcache_bytes, 24 * 1024);
        c.validate().unwrap();
    }

    #[test]
    fn hyp_capacity_is_sane() {
        let c = AccelConfig::paper();
        // 24 KB / 32 B / 2 = 384 live hypotheses.
        assert_eq!(c.hyp_capacity(), 384);
    }

    #[test]
    fn validate_rejects_degenerate() {
        let mut c = AccelConfig::paper();
        c.num_pes = 0;
        assert!(c.validate().is_err());
        let mut c = AccelConfig::paper();
        c.mac_vector_width = 6;
        assert!(c.validate().is_err());
    }
}
