//! Model topology descriptions — the paper's case-study TDS network (§4)
//! and the tiny trained variant used by the functional end-to-end path.
//!
//! The paper-scale preset reproduces the §4.2 kernel inventory exactly:
//! **18 CONV, 29 FC and 32 LayerNorm kernels** (79 acoustic-model kernels)
//! over 80-dim MFCC features, emitting scores for 9000 word-pieces. The
//! same [`ModelConfig`] drives the accelerator simulator (instruction
//! counts, Fig. 11), the layer-size report (Fig. 9) and the native AM
//! shape checks, so all experiments see one consistent workload.

/// One layer of the TDS acoustic model, in execution order.
///
/// Convolutions are 2D over (time × mel-width) with full channel mixing
/// and kernel `(kw, 1)`, the TDS formulation: an input of `in_ch` channels
/// by `w` mel bands convolved along time only. They are **causal** (left
/// context only) so streaming execution with a `(kw-1)`-deep state buffer
/// reproduces offline outputs exactly.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    Conv {
        name: String,
        in_ch: usize,
        out_ch: usize,
        /// Temporal kernel width.
        kw: usize,
        /// Temporal stride (subsampling).
        stride: usize,
        /// Mel-band width the channels are laid over (80 in the paper).
        w: usize,
        /// True for the conv inside a TDS block (has a residual add).
        residual: bool,
    },
    Fc {
        name: String,
        in_dim: usize,
        out_dim: usize,
        /// ReLU after this FC (first FC of a TDS block pair; the output
        /// layer and second FCs are linear).
        relu: bool,
        /// True for the second FC of a TDS block pair (residual add).
        residual: bool,
    },
    LayerNorm { name: String, dim: usize },
}

impl Layer {
    pub fn name(&self) -> &str {
        match self {
            Layer::Conv { name, .. } | Layer::Fc { name, .. } | Layer::LayerNorm { name, .. } => {
                name
            }
        }
    }

    /// Number of trainable parameters (weights + biases / gains).
    pub fn params(&self) -> usize {
        match self {
            Layer::Conv {
                in_ch, out_ch, kw, ..
            } => in_ch * out_ch * kw + out_ch,
            Layer::Fc {
                in_dim, out_dim, ..
            } => in_dim * out_dim + out_dim,
            Layer::LayerNorm { dim, .. } => 2 * dim,
        }
    }

    /// Model-data bytes for this layer as stored in model memory, from
    /// the precision's per-weight bit width (floor division — sub-byte
    /// formats never round a layer *up*, so int4 is at most half of int8
    /// exactly). The paper quantizes weights to 8 bits (the MAC unit
    /// consumes 8-bit vectors); the below-int8 formats push further:
    /// 4 bits packed, or 3 bits effective for 2:4 structured sparsity.
    pub fn model_bytes(&self, precision: Precision) -> usize {
        self.params() * precision.weight_bits() / 8
    }

    /// Multiply-accumulates needed to produce ONE output timestep.
    pub fn macs_per_timestep(&self) -> usize {
        match self {
            Layer::Conv {
                in_ch,
                out_ch,
                kw,
                w,
                ..
            } => in_ch * out_ch * kw * w,
            Layer::Fc {
                in_dim, out_dim, ..
            } => in_dim * out_dim,
            // LayerNorm is not MAC work; costed separately.
            Layer::LayerNorm { .. } => 0,
        }
    }

    /// Number of kernel threads ASRPU launches per output timestep
    /// (§3.1: "each thread computes a single neuron"; LayerNorm threads
    /// each normalize one timestep vector).
    pub fn threads_per_timestep(&self, w: usize) -> usize {
        match self {
            Layer::Conv { out_ch, .. } => out_ch * w,
            Layer::Fc { out_dim, .. } => *out_dim,
            Layer::LayerNorm { .. } => 1,
        }
    }

    /// Per-thread dot-product length (inputs accumulated by one neuron).
    pub fn dot_len(&self) -> usize {
        match self {
            Layer::Conv { in_ch, kw, .. } => in_ch * kw,
            Layer::Fc { in_dim, .. } => *in_dim,
            Layer::LayerNorm { dim, .. } => *dim,
        }
    }
}

/// Numeric precision of the stored model weights — the `config` knob
/// behind both halves of the system: the native engine selects between
/// [`crate::am::TdsModel`] (f32) and [`crate::am::QuantizedTdsModel`]
/// (quantized weights, f32 accumulate), and the accelerator simulator
/// derives weight-traffic bytes from it (int8 ⇒ 4× less model-data
/// bandwidth than f32, the paper's §3.4 MAC-unit assumption; the
/// below-int8 formats halve that again or better).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// 32-bit float weights (the functional reference path).
    F32,
    /// 8-bit affine-quantized weights, per-output-row scale/zero-point
    /// (the paper's deployment path).
    Int8,
    /// 4-bit affine-quantized weights packed two per byte, per-group
    /// scale/zero-point (`am::quant::INT4_GROUP` columns per group).
    Int4,
    /// 2:4 structured-sparse int4: per 4-weight block the 2 largest-
    /// magnitude weights survive as 4-bit values plus 2-bit in-block
    /// indices — 12 bits per 4 weights, 3 bits/weight effective.
    Int4Sparse,
}

impl Precision {
    /// Bits one weight occupies in model memory / DMA traffic. Sub-byte
    /// formats are why this is bits, not bytes: int4 packs two weights
    /// per byte, and 2:4 sparse stores 12 bits per 4-weight block.
    pub fn weight_bits(self) -> usize {
        match self {
            Precision::F32 => 32,
            Precision::Int8 => 8,
            Precision::Int4 => 4,
            Precision::Int4Sparse => 3,
        }
    }

    /// Bytes one *activation* element occupies on-chip (shared memory,
    /// inter-step state). Quantized deployments move int8 activations
    /// regardless of how far the weights are compressed.
    pub fn activation_bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Int8 | Precision::Int4 | Precision::Int4Sparse => 1,
        }
    }

    pub fn is_quantized(self) -> bool {
        !matches!(self, Precision::F32)
    }

    /// Canonical lowercase token, the inverse of [`Precision::parse`].
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
            Precision::Int4Sparse => "int4_sparse",
        }
    }

    /// Parse a canonical token (`f32`, `int8`, `int4`, `int4_sparse`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim() {
            "f32" => Ok(Precision::F32),
            "int8" => Ok(Precision::Int8),
            "int4" => Ok(Precision::Int4),
            "int4_sparse" => Ok(Precision::Int4Sparse),
            other => Err(format!(
                "unknown precision '{other}' (expected f32|int8|int4|int4_sparse)"
            )),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Per-layer weight-precision assignment: a default for every layer plus
/// named overrides, the output of the compile-side calibration pass
/// (`python/compile/calibrate.py`). A uniform map (no overrides) behaves
/// exactly like the scalar [`Precision`] knob it generalizes.
///
/// Overrides are keyed by [`Layer::name`] and applied first-match-wins;
/// LayerNorm layers always execute in f32 regardless of the map (they
/// are not MAC work and their 2·dim parameters are noise), which the
/// accelerator accounting mirrors.
#[derive(Debug, Clone, PartialEq)]
pub struct PrecisionMap {
    /// Precision for any layer without an override.
    pub default: Precision,
    /// `(layer name, precision)` overrides, first match wins.
    pub overrides: Vec<(String, Precision)>,
}

impl PrecisionMap {
    /// A map that assigns `p` to every layer.
    pub fn uniform(p: Precision) -> Self {
        PrecisionMap { default: p, overrides: Vec::new() }
    }

    /// Precision for the layer named `name`.
    pub fn resolve(&self, name: &str) -> Precision {
        self.overrides
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| *p)
            .unwrap_or(self.default)
    }

    /// Add (or replace) an override for `name`.
    pub fn set(&mut self, name: &str, p: Precision) {
        if let Some(slot) = self.overrides.iter_mut().find(|(n, _)| n == name) {
            slot.1 = p;
        } else {
            self.overrides.push((name.to_string(), p));
        }
    }

    /// True when every layer resolves to the same precision.
    pub fn is_uniform(&self) -> bool {
        self.overrides.iter().all(|(_, p)| *p == self.default)
    }

    /// Parse the CLI/protocol syntax: a default token optionally followed
    /// by `,name=token` overrides, e.g. `int4,output.fc=int8,g0.sub=f32`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut parts = s.split(',');
        let default = Precision::parse(parts.next().unwrap_or(""))?;
        let mut map = PrecisionMap::uniform(default);
        for part in parts {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, tok) = part
                .split_once('=')
                .ok_or_else(|| format!("precision override '{part}' is not name=precision"))?;
            map.set(name.trim(), Precision::parse(tok)?);
        }
        Ok(map)
    }

    /// Load a calibrated per-layer map from `artifacts/precision.bin`
    /// (written by `python/compile/calibrate.py`): a `tensor_io` file
    /// whose u32 tensor `precision.codes` holds one code per layer of
    /// `cfg.layers()`, 0=f32 1=int8 2=int4 3=int4_sparse. The default
    /// becomes the most common code; the rest become overrides.
    pub fn from_artifacts(cfg: &ModelConfig, dir: &std::path::Path) -> Result<Self, String> {
        let tf = crate::util::tensor_io::TensorFile::load(&dir.join("precision.bin"))
            .map_err(|e| format!("loading precision.bin: {e}"))?;
        let t = tf
            .require("precision.codes")
            .map_err(|e| format!("precision.bin: {e}"))?;
        let codes = t.as_u32().map_err(|e| format!("precision.codes: {e}"))?;
        let layers = cfg.layers();
        if codes.len() != layers.len() {
            return Err(format!(
                "precision.codes has {} entries for {} layers",
                codes.len(),
                layers.len()
            ));
        }
        let decode = |c: u32| match c {
            0 => Ok(Precision::F32),
            1 => Ok(Precision::Int8),
            2 => Ok(Precision::Int4),
            3 => Ok(Precision::Int4Sparse),
            other => Err(format!("precision code {other} out of range")),
        };
        let mut counts = [0usize; 4];
        for &c in codes {
            decode(c)?;
            counts[c as usize] += 1;
        }
        let default_code =
            (0..4u32).max_by_key(|&c| counts[c as usize]).unwrap_or(0);
        let mut map = PrecisionMap::uniform(decode(default_code)?);
        for (layer, &c) in layers.iter().zip(codes) {
            if c != default_code {
                map.set(layer.name(), decode(c)?);
            }
        }
        Ok(map)
    }

    /// Check every override names a real layer of `cfg`.
    pub fn validate(&self, cfg: &ModelConfig) -> Result<(), String> {
        let layers = cfg.layers();
        for (name, _) in &self.overrides {
            if !layers.iter().any(|l| l.name() == name) {
                return Err(format!("precision override for unknown layer '{name}'"));
            }
        }
        Ok(())
    }
}

impl std::fmt::Display for PrecisionMap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.default)?;
        for (name, p) in &self.overrides {
            write!(f, ",{name}={p}")?;
        }
        Ok(())
    }
}

/// One TDS group: `blocks` TDS blocks at `channels` channels, entered
/// through a standalone subsampling conv.
#[derive(Debug, Clone, PartialEq)]
pub struct Group {
    pub channels: usize,
    pub blocks: usize,
    /// Temporal kernel width of convs in this group.
    pub kw: usize,
    /// Stride of the group's entry conv.
    pub entry_stride: usize,
}

/// Complete description of an ASR model + front-end geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Audio sample rate (Hz).
    pub sample_rate: usize,
    /// MFCC analysis window (samples) — 25 ms.
    pub win_len: usize,
    /// MFCC hop (samples) — 10 ms.
    pub hop_len: usize,
    /// Mel bands / feature dimension (80 in the paper).
    pub n_mels: usize,
    /// Audio per decoding step (samples) — 80 ms ⇒ 8 feature frames.
    pub step_len: usize,
    /// TDS groups.
    pub groups: Vec<Group>,
    /// Optional final context conv (kw) at the last group's channels.
    pub final_conv_kw: Option<usize>,
    /// Output tokens (9000 word-pieces in the paper; blank = id 0).
    pub tokens: usize,
    /// Weight precision: int8-quantized (paper) or f32 (functional).
    pub precision: Precision,
}

impl ModelConfig {
    /// The paper's case-study network (§4.2, §5.2): 80-dim MFCC, three TDS
    /// groups split by a 2× subsampling entry conv on the first group, a
    /// final context conv, and a 9000-way word-piece output layer.
    /// Yields exactly 18 CONV / 29 FC / 32 LN kernels.
    pub fn paper_tds() -> Self {
        ModelConfig {
            name: "paper-tds".into(),
            sample_rate: 16_000,
            win_len: 400,
            hop_len: 160,
            n_mels: 80,
            step_len: 1280,
            groups: vec![
                Group { channels: 10, blocks: 4, kw: 21, entry_stride: 2 },
                Group { channels: 12, blocks: 5, kw: 21, entry_stride: 1 },
                Group { channels: 15, blocks: 5, kw: 21, entry_stride: 1 },
            ],
            final_conv_kw: Some(11),
            tokens: 9000,
            precision: Precision::Int8,
        }
    }

    /// The tiny trained variant used end-to-end (see python/compile):
    /// same structure, small dims, 27 tokens (blank + 26 syllables).
    pub fn tiny_tds() -> Self {
        ModelConfig {
            name: "tiny-tds".into(),
            sample_rate: 16_000,
            win_len: 400,
            hop_len: 160,
            n_mels: 40,
            step_len: 1280,
            groups: vec![
                Group { channels: 2, blocks: 1, kw: 5, entry_stride: 2 },
                Group { channels: 3, blocks: 2, kw: 5, entry_stride: 1 },
            ],
            final_conv_kw: None,
            tokens: 27,
            precision: Precision::F32,
        }
    }

    /// Overall temporal subsampling factor (feature frames per acoustic
    /// score vector).
    pub fn subsample(&self) -> usize {
        self.groups.iter().map(|g| g.entry_stride).product()
    }

    /// Feature frames produced per decoding step.
    pub fn frames_per_step(&self) -> usize {
        self.step_len / self.hop_len
    }

    /// Acoustic score vectors per decoding step (hypothesis-expansion
    /// repetitions, Fig. 6).
    pub fn vectors_per_step(&self) -> usize {
        self.frames_per_step() / self.subsample()
    }

    /// Samples the front-end must see per step: `step_len` new samples
    /// plus the `win_len - hop_len` look-back tail.
    pub fn samples_per_step(&self) -> usize {
        self.step_len + self.win_len - self.hop_len
    }

    /// Audio seconds per decoding step.
    pub fn step_seconds(&self) -> f64 {
        self.step_len as f64 / self.sample_rate as f64
    }

    /// The full layer sequence in execution order.
    pub fn layers(&self) -> Vec<Layer> {
        let mut layers = Vec::new();
        let mut in_ch = 1; // MFCC frame enters as 1 channel × n_mels
        for (gi, g) in self.groups.iter().enumerate() {
            let c = g.channels;
            layers.push(Layer::Conv {
                name: format!("g{gi}.sub"),
                in_ch,
                out_ch: c,
                kw: g.kw,
                stride: g.entry_stride,
                w: self.n_mels,
                residual: false,
            });
            layers.push(Layer::LayerNorm {
                name: format!("g{gi}.sub.ln"),
                dim: c * self.n_mels,
            });
            for b in 0..g.blocks {
                let dim = c * self.n_mels;
                layers.push(Layer::Conv {
                    name: format!("g{gi}.b{b}.conv"),
                    in_ch: c,
                    out_ch: c,
                    kw: g.kw,
                    stride: 1,
                    w: self.n_mels,
                    residual: true,
                });
                layers.push(Layer::LayerNorm {
                    name: format!("g{gi}.b{b}.ln0"),
                    dim,
                });
                layers.push(Layer::Fc {
                    name: format!("g{gi}.b{b}.fc0"),
                    in_dim: dim,
                    out_dim: dim,
                    relu: true,
                    residual: false,
                });
                layers.push(Layer::Fc {
                    name: format!("g{gi}.b{b}.fc1"),
                    in_dim: dim,
                    out_dim: dim,
                    relu: false,
                    residual: true,
                });
                layers.push(Layer::LayerNorm {
                    name: format!("g{gi}.b{b}.ln1"),
                    dim,
                });
            }
            in_ch = c;
        }
        let last_c = self.groups.last().map(|g| g.channels).unwrap_or(1);
        if let Some(kw) = self.final_conv_kw {
            layers.push(Layer::Conv {
                name: "final.conv".into(),
                in_ch: last_c,
                out_ch: last_c,
                kw,
                stride: 1,
                w: self.n_mels,
                residual: false,
            });
            layers.push(Layer::LayerNorm {
                name: "final.ln".into(),
                dim: last_c * self.n_mels,
            });
        }
        layers.push(Layer::Fc {
            name: "output.fc".into(),
            in_dim: last_c * self.n_mels,
            out_dim: self.tokens,
            relu: false,
            residual: false,
        });
        layers
    }

    /// (conv, fc, layernorm) kernel counts — the §4.2 inventory.
    pub fn kernel_counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for l in self.layers() {
            match l {
                Layer::Conv { .. } => c.0 += 1,
                Layer::Fc { .. } => c.1 += 1,
                Layer::LayerNorm { .. } => c.2 += 1,
            }
        }
        c
    }

    /// Total model-data bytes.
    pub fn model_bytes(&self) -> usize {
        self.layers().iter().map(|l| l.model_bytes(self.precision)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_tds_matches_section_4_2_inventory() {
        let m = ModelConfig::paper_tds();
        assert_eq!(m.kernel_counts(), (18, 29, 32), "18 CONV, 29 FC, 32 LN");
        assert_eq!(m.layers().len(), 79, "79 acoustic-model kernels");
    }

    #[test]
    fn paper_geometry() {
        let m = ModelConfig::paper_tds();
        assert_eq!(m.frames_per_step(), 8, "80 ms step, 10 ms hop");
        assert_eq!(m.subsample(), 2);
        assert_eq!(m.vectors_per_step(), 4);
        assert_eq!(m.samples_per_step(), 1520, "80 ms + 15 ms tail");
        assert!((m.step_seconds() - 0.080).abs() < 1e-9);
    }

    #[test]
    fn fc_layer_sizes_match_section_5_2() {
        // §5.2: "each of the first FC layers consists of 1200 neurons with
        // 1200 inputs each, which results in 1.4MB of model data" — that is
        // the widest group's FCs at int8.
        let m = ModelConfig::paper_tds();
        let fc_bytes: Vec<usize> = m
            .layers()
            .iter()
            .filter(|l| matches!(l, Layer::Fc { .. }))
            .map(|l| l.model_bytes(Precision::Int8))
            .collect();
        let max_hidden_fc = fc_bytes[..fc_bytes.len() - 1].iter().max().unwrap();
        assert!(
            (1_350_000..1_500_000).contains(max_hidden_fc),
            "widest hidden FC ≈1.4 MB, got {max_hidden_fc}"
        );
        // Output layer 1200×9000 ≈ 10.8 MB — must be split (tested in accel).
        assert!(*fc_bytes.last().unwrap() > 10_000_000);
    }

    #[test]
    fn conv_layers_are_a_few_kb() {
        // §5.2: "Convolutional layers fit in a few KB".
        let m = ModelConfig::paper_tds();
        for l in m.layers() {
            if matches!(l, Layer::Conv { .. }) {
                let kb = l.model_bytes(Precision::Int8) / 1024;
                assert!(kb < 8, "conv layer {} is {kb} KB", l.name());
            }
        }
    }

    #[test]
    fn tiny_tds_is_consistent() {
        let m = ModelConfig::tiny_tds();
        assert_eq!(m.subsample(), 2);
        assert_eq!(m.vectors_per_step(), 4);
        assert_eq!(m.tokens, 27);
        // Small enough to train at build time.
        assert!(m.layers().iter().map(|l| l.params()).sum::<usize>() < 300_000);
    }

    #[test]
    fn weight_bits_orders_and_int4_halves_int8() {
        assert!(Precision::F32.weight_bits() > Precision::Int8.weight_bits());
        assert!(Precision::Int8.weight_bits() > Precision::Int4.weight_bits());
        assert!(Precision::Int4.weight_bits() > Precision::Int4Sparse.weight_bits());
        // Per layer: int8 bytes ≥ 2× int4 bytes (floor math never flips it).
        for l in ModelConfig::paper_tds().layers() {
            let b8 = l.model_bytes(Precision::Int8);
            let b4 = l.model_bytes(Precision::Int4);
            assert!(b8 >= 2 * b4, "layer {}: int8 {b8} < 2× int4 {b4}", l.name());
            assert!(l.model_bytes(Precision::Int4Sparse) <= b4);
        }
    }

    #[test]
    fn precision_tokens_round_trip() {
        for p in [Precision::F32, Precision::Int8, Precision::Int4, Precision::Int4Sparse] {
            assert_eq!(Precision::parse(p.as_str()), Ok(p));
        }
        assert!(Precision::parse("int2").is_err());
    }

    #[test]
    fn precision_map_resolve_and_round_trip() {
        let mut map = PrecisionMap::uniform(Precision::Int4);
        assert!(map.is_uniform());
        map.set("output.fc", Precision::Int8);
        map.set("g0.sub", Precision::F32);
        map.set("g0.sub", Precision::Int4Sparse); // replace, not append
        assert!(!map.is_uniform());
        assert_eq!(map.resolve("output.fc"), Precision::Int8);
        assert_eq!(map.resolve("g0.sub"), Precision::Int4Sparse);
        assert_eq!(map.resolve("g1.b0.fc0"), Precision::Int4);
        let parsed = PrecisionMap::parse(&map.to_string()).unwrap();
        assert_eq!(parsed, map);
        assert!(map.validate(&ModelConfig::paper_tds()).is_ok());
        map.set("no.such.layer", Precision::Int8);
        assert!(map.validate(&ModelConfig::paper_tds()).is_err());
        assert!(PrecisionMap::parse("int4,oops").is_err());
        assert!(PrecisionMap::parse("int3").is_err());
    }

    #[test]
    fn precision_map_from_artifacts_codes() {
        use crate::util::tensor_io::{Tensor, TensorFile};
        let cfg = ModelConfig::tiny_tds();
        let n = cfg.layers().len();
        // Mostly int4, output layer int8, entry conv f32.
        let mut codes = vec![2u32; n];
        codes[0] = 0;
        codes[n - 1] = 1;
        let dir = std::env::temp_dir().join(format!("asrpu-pmap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut tf = TensorFile::new();
        tf.push(Tensor::u32("precision.codes", vec![n], codes));
        tf.save(&dir.join("precision.bin")).unwrap();
        let map = PrecisionMap::from_artifacts(&cfg, &dir).unwrap();
        assert_eq!(map.default, Precision::Int4);
        let layers = cfg.layers();
        assert_eq!(map.resolve(layers[0].name()), Precision::F32);
        assert_eq!(map.resolve(layers[n - 1].name()), Precision::Int8);
        assert_eq!(map.resolve(layers[1].name()), Precision::Int4);
        // Wrong length errors.
        let mut tf = TensorFile::new();
        tf.push(Tensor::u32("precision.codes", vec![2], vec![2, 2]));
        tf.save(&dir.join("precision.bin")).unwrap();
        assert!(PrecisionMap::from_artifacts(&cfg, &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn layer_shapes_chain() {
        // Output dim of each layer must equal input dim of the next
        // (conv/fc dims expressed over c*w flattening).
        for m in [ModelConfig::paper_tds(), ModelConfig::tiny_tds()] {
            let mut cur = m.n_mels; // 1 channel × n_mels
            for l in m.layers() {
                match &l {
                    Layer::Conv { in_ch, out_ch, w, .. } => {
                        assert_eq!(cur, in_ch * w, "layer {}", l.name());
                        cur = out_ch * w;
                    }
                    Layer::Fc { in_dim, out_dim, .. } => {
                        assert_eq!(cur, *in_dim, "layer {}", l.name());
                        cur = *out_dim;
                    }
                    Layer::LayerNorm { dim, .. } => {
                        assert_eq!(cur, *dim, "layer {}", l.name());
                    }
                }
            }
            assert_eq!(cur, m.tokens);
        }
    }
}
