//! Model topology descriptions — the paper's case-study TDS network (§4)
//! and the tiny trained variant used by the functional end-to-end path.
//!
//! The paper-scale preset reproduces the §4.2 kernel inventory exactly:
//! **18 CONV, 29 FC and 32 LayerNorm kernels** (79 acoustic-model kernels)
//! over 80-dim MFCC features, emitting scores for 9000 word-pieces. The
//! same [`ModelConfig`] drives the accelerator simulator (instruction
//! counts, Fig. 11), the layer-size report (Fig. 9) and the native AM
//! shape checks, so all experiments see one consistent workload.

/// One layer of the TDS acoustic model, in execution order.
///
/// Convolutions are 2D over (time × mel-width) with full channel mixing
/// and kernel `(kw, 1)`, the TDS formulation: an input of `in_ch` channels
/// by `w` mel bands convolved along time only. They are **causal** (left
/// context only) so streaming execution with a `(kw-1)`-deep state buffer
/// reproduces offline outputs exactly.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    Conv {
        name: String,
        in_ch: usize,
        out_ch: usize,
        /// Temporal kernel width.
        kw: usize,
        /// Temporal stride (subsampling).
        stride: usize,
        /// Mel-band width the channels are laid over (80 in the paper).
        w: usize,
        /// True for the conv inside a TDS block (has a residual add).
        residual: bool,
    },
    Fc {
        name: String,
        in_dim: usize,
        out_dim: usize,
        /// ReLU after this FC (first FC of a TDS block pair; the output
        /// layer and second FCs are linear).
        relu: bool,
        /// True for the second FC of a TDS block pair (residual add).
        residual: bool,
    },
    LayerNorm { name: String, dim: usize },
}

impl Layer {
    pub fn name(&self) -> &str {
        match self {
            Layer::Conv { name, .. } | Layer::Fc { name, .. } | Layer::LayerNorm { name, .. } => {
                name
            }
        }
    }

    /// Number of trainable parameters (weights + biases / gains).
    pub fn params(&self) -> usize {
        match self {
            Layer::Conv {
                in_ch, out_ch, kw, ..
            } => in_ch * out_ch * kw + out_ch,
            Layer::Fc {
                in_dim, out_dim, ..
            } => in_dim * out_dim + out_dim,
            Layer::LayerNorm { dim, .. } => 2 * dim,
        }
    }

    /// Model-data bytes for this layer as stored in model memory.
    /// The paper quantizes weights to 8 bits (the MAC unit consumes 8-bit
    /// vectors), so int8 ⇒ 1 byte/param; the functional f32 path uses 4.
    pub fn model_bytes(&self, precision: Precision) -> usize {
        self.params() * precision.bytes_per_weight()
    }

    /// Multiply-accumulates needed to produce ONE output timestep.
    pub fn macs_per_timestep(&self) -> usize {
        match self {
            Layer::Conv {
                in_ch,
                out_ch,
                kw,
                w,
                ..
            } => in_ch * out_ch * kw * w,
            Layer::Fc {
                in_dim, out_dim, ..
            } => in_dim * out_dim,
            // LayerNorm is not MAC work; costed separately.
            Layer::LayerNorm { .. } => 0,
        }
    }

    /// Number of kernel threads ASRPU launches per output timestep
    /// (§3.1: "each thread computes a single neuron"; LayerNorm threads
    /// each normalize one timestep vector).
    pub fn threads_per_timestep(&self, w: usize) -> usize {
        match self {
            Layer::Conv { out_ch, .. } => out_ch * w,
            Layer::Fc { out_dim, .. } => *out_dim,
            Layer::LayerNorm { .. } => 1,
        }
    }

    /// Per-thread dot-product length (inputs accumulated by one neuron).
    pub fn dot_len(&self) -> usize {
        match self {
            Layer::Conv { in_ch, kw, .. } => in_ch * kw,
            Layer::Fc { in_dim, .. } => *in_dim,
            Layer::LayerNorm { dim, .. } => *dim,
        }
    }
}

/// Numeric precision of the stored model weights — the `config` knob
/// behind both halves of the system: the native engine selects between
/// [`crate::am::TdsModel`] (f32) and [`crate::am::QuantizedTdsModel`]
/// (int8 weights, f32 accumulate), and the accelerator simulator derives
/// weight-traffic bytes from it (int8 ⇒ 4× less model-data bandwidth,
/// the paper's §3.4 MAC-unit assumption).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// 32-bit float weights (the functional reference path).
    F32,
    /// 8-bit affine-quantized weights, per-output-row scale/zero-point
    /// (the paper's deployment path).
    Int8,
}

impl Precision {
    /// Bytes one weight occupies in model memory / DMA traffic.
    pub fn bytes_per_weight(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Int8 => 1,
        }
    }

    pub fn is_quantized(self) -> bool {
        matches!(self, Precision::Int8)
    }
}

/// One TDS group: `blocks` TDS blocks at `channels` channels, entered
/// through a standalone subsampling conv.
#[derive(Debug, Clone, PartialEq)]
pub struct Group {
    pub channels: usize,
    pub blocks: usize,
    /// Temporal kernel width of convs in this group.
    pub kw: usize,
    /// Stride of the group's entry conv.
    pub entry_stride: usize,
}

/// Complete description of an ASR model + front-end geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Audio sample rate (Hz).
    pub sample_rate: usize,
    /// MFCC analysis window (samples) — 25 ms.
    pub win_len: usize,
    /// MFCC hop (samples) — 10 ms.
    pub hop_len: usize,
    /// Mel bands / feature dimension (80 in the paper).
    pub n_mels: usize,
    /// Audio per decoding step (samples) — 80 ms ⇒ 8 feature frames.
    pub step_len: usize,
    /// TDS groups.
    pub groups: Vec<Group>,
    /// Optional final context conv (kw) at the last group's channels.
    pub final_conv_kw: Option<usize>,
    /// Output tokens (9000 word-pieces in the paper; blank = id 0).
    pub tokens: usize,
    /// Weight precision: int8-quantized (paper) or f32 (functional).
    pub precision: Precision,
}

impl ModelConfig {
    /// The paper's case-study network (§4.2, §5.2): 80-dim MFCC, three TDS
    /// groups split by a 2× subsampling entry conv on the first group, a
    /// final context conv, and a 9000-way word-piece output layer.
    /// Yields exactly 18 CONV / 29 FC / 32 LN kernels.
    pub fn paper_tds() -> Self {
        ModelConfig {
            name: "paper-tds".into(),
            sample_rate: 16_000,
            win_len: 400,
            hop_len: 160,
            n_mels: 80,
            step_len: 1280,
            groups: vec![
                Group { channels: 10, blocks: 4, kw: 21, entry_stride: 2 },
                Group { channels: 12, blocks: 5, kw: 21, entry_stride: 1 },
                Group { channels: 15, blocks: 5, kw: 21, entry_stride: 1 },
            ],
            final_conv_kw: Some(11),
            tokens: 9000,
            precision: Precision::Int8,
        }
    }

    /// The tiny trained variant used end-to-end (see python/compile):
    /// same structure, small dims, 27 tokens (blank + 26 syllables).
    pub fn tiny_tds() -> Self {
        ModelConfig {
            name: "tiny-tds".into(),
            sample_rate: 16_000,
            win_len: 400,
            hop_len: 160,
            n_mels: 40,
            step_len: 1280,
            groups: vec![
                Group { channels: 2, blocks: 1, kw: 5, entry_stride: 2 },
                Group { channels: 3, blocks: 2, kw: 5, entry_stride: 1 },
            ],
            final_conv_kw: None,
            tokens: 27,
            precision: Precision::F32,
        }
    }

    /// Overall temporal subsampling factor (feature frames per acoustic
    /// score vector).
    pub fn subsample(&self) -> usize {
        self.groups.iter().map(|g| g.entry_stride).product()
    }

    /// Feature frames produced per decoding step.
    pub fn frames_per_step(&self) -> usize {
        self.step_len / self.hop_len
    }

    /// Acoustic score vectors per decoding step (hypothesis-expansion
    /// repetitions, Fig. 6).
    pub fn vectors_per_step(&self) -> usize {
        self.frames_per_step() / self.subsample()
    }

    /// Samples the front-end must see per step: `step_len` new samples
    /// plus the `win_len - hop_len` look-back tail.
    pub fn samples_per_step(&self) -> usize {
        self.step_len + self.win_len - self.hop_len
    }

    /// Audio seconds per decoding step.
    pub fn step_seconds(&self) -> f64 {
        self.step_len as f64 / self.sample_rate as f64
    }

    /// The full layer sequence in execution order.
    pub fn layers(&self) -> Vec<Layer> {
        let mut layers = Vec::new();
        let mut in_ch = 1; // MFCC frame enters as 1 channel × n_mels
        for (gi, g) in self.groups.iter().enumerate() {
            let c = g.channels;
            layers.push(Layer::Conv {
                name: format!("g{gi}.sub"),
                in_ch,
                out_ch: c,
                kw: g.kw,
                stride: g.entry_stride,
                w: self.n_mels,
                residual: false,
            });
            layers.push(Layer::LayerNorm {
                name: format!("g{gi}.sub.ln"),
                dim: c * self.n_mels,
            });
            for b in 0..g.blocks {
                let dim = c * self.n_mels;
                layers.push(Layer::Conv {
                    name: format!("g{gi}.b{b}.conv"),
                    in_ch: c,
                    out_ch: c,
                    kw: g.kw,
                    stride: 1,
                    w: self.n_mels,
                    residual: true,
                });
                layers.push(Layer::LayerNorm {
                    name: format!("g{gi}.b{b}.ln0"),
                    dim,
                });
                layers.push(Layer::Fc {
                    name: format!("g{gi}.b{b}.fc0"),
                    in_dim: dim,
                    out_dim: dim,
                    relu: true,
                    residual: false,
                });
                layers.push(Layer::Fc {
                    name: format!("g{gi}.b{b}.fc1"),
                    in_dim: dim,
                    out_dim: dim,
                    relu: false,
                    residual: true,
                });
                layers.push(Layer::LayerNorm {
                    name: format!("g{gi}.b{b}.ln1"),
                    dim,
                });
            }
            in_ch = c;
        }
        let last_c = self.groups.last().map(|g| g.channels).unwrap_or(1);
        if let Some(kw) = self.final_conv_kw {
            layers.push(Layer::Conv {
                name: "final.conv".into(),
                in_ch: last_c,
                out_ch: last_c,
                kw,
                stride: 1,
                w: self.n_mels,
                residual: false,
            });
            layers.push(Layer::LayerNorm {
                name: "final.ln".into(),
                dim: last_c * self.n_mels,
            });
        }
        layers.push(Layer::Fc {
            name: "output.fc".into(),
            in_dim: last_c * self.n_mels,
            out_dim: self.tokens,
            relu: false,
            residual: false,
        });
        layers
    }

    /// (conv, fc, layernorm) kernel counts — the §4.2 inventory.
    pub fn kernel_counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for l in self.layers() {
            match l {
                Layer::Conv { .. } => c.0 += 1,
                Layer::Fc { .. } => c.1 += 1,
                Layer::LayerNorm { .. } => c.2 += 1,
            }
        }
        c
    }

    /// Total model-data bytes.
    pub fn model_bytes(&self) -> usize {
        self.layers().iter().map(|l| l.model_bytes(self.precision)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_tds_matches_section_4_2_inventory() {
        let m = ModelConfig::paper_tds();
        assert_eq!(m.kernel_counts(), (18, 29, 32), "18 CONV, 29 FC, 32 LN");
        assert_eq!(m.layers().len(), 79, "79 acoustic-model kernels");
    }

    #[test]
    fn paper_geometry() {
        let m = ModelConfig::paper_tds();
        assert_eq!(m.frames_per_step(), 8, "80 ms step, 10 ms hop");
        assert_eq!(m.subsample(), 2);
        assert_eq!(m.vectors_per_step(), 4);
        assert_eq!(m.samples_per_step(), 1520, "80 ms + 15 ms tail");
        assert!((m.step_seconds() - 0.080).abs() < 1e-9);
    }

    #[test]
    fn fc_layer_sizes_match_section_5_2() {
        // §5.2: "each of the first FC layers consists of 1200 neurons with
        // 1200 inputs each, which results in 1.4MB of model data" — that is
        // the widest group's FCs at int8.
        let m = ModelConfig::paper_tds();
        let fc_bytes: Vec<usize> = m
            .layers()
            .iter()
            .filter(|l| matches!(l, Layer::Fc { .. }))
            .map(|l| l.model_bytes(Precision::Int8))
            .collect();
        let max_hidden_fc = fc_bytes[..fc_bytes.len() - 1].iter().max().unwrap();
        assert!(
            (1_350_000..1_500_000).contains(max_hidden_fc),
            "widest hidden FC ≈1.4 MB, got {max_hidden_fc}"
        );
        // Output layer 1200×9000 ≈ 10.8 MB — must be split (tested in accel).
        assert!(*fc_bytes.last().unwrap() > 10_000_000);
    }

    #[test]
    fn conv_layers_are_a_few_kb() {
        // §5.2: "Convolutional layers fit in a few KB".
        let m = ModelConfig::paper_tds();
        for l in m.layers() {
            if matches!(l, Layer::Conv { .. }) {
                let kb = l.model_bytes(Precision::Int8) / 1024;
                assert!(kb < 8, "conv layer {} is {kb} KB", l.name());
            }
        }
    }

    #[test]
    fn tiny_tds_is_consistent() {
        let m = ModelConfig::tiny_tds();
        assert_eq!(m.subsample(), 2);
        assert_eq!(m.vectors_per_step(), 4);
        assert_eq!(m.tokens, 27);
        // Small enough to train at build time.
        assert!(m.layers().iter().map(|l| l.params()).sum::<usize>() < 300_000);
    }

    #[test]
    fn layer_shapes_chain() {
        // Output dim of each layer must equal input dim of the next
        // (conv/fc dims expressed over c*w flattening).
        for m in [ModelConfig::paper_tds(), ModelConfig::tiny_tds()] {
            let mut cur = m.n_mels; // 1 channel × n_mels
            for l in m.layers() {
                match &l {
                    Layer::Conv { in_ch, out_ch, w, .. } => {
                        assert_eq!(cur, in_ch * w, "layer {}", l.name());
                        cur = out_ch * w;
                    }
                    Layer::Fc { in_dim, out_dim, .. } => {
                        assert_eq!(cur, *in_dim, "layer {}", l.name());
                        cur = *out_dim;
                    }
                    Layer::LayerNorm { dim, .. } => {
                        assert_eq!(cur, *dim, "layer {}", l.name());
                    }
                }
            }
            assert_eq!(cur, m.tokens);
        }
    }
}
