//! Configuration: accelerator hardware (Table 2), model topology (§4) and
//! decoder search parameters, plus artifact-directory resolution.

pub mod accel;
pub mod model;
pub mod pipeline;

pub use accel::AccelConfig;
pub use model::{Group, Layer, ModelConfig, Precision};
pub use pipeline::{PipelineDesc, StageDesc};

/// Re-exported so config consumers (serving introspection, the
/// simulator's host accounting) can name the host kernel ISA without
/// reaching into `am::gemm`.
pub use crate::am::gemm::dispatch::KernelIsa;

use std::path::{Path, PathBuf};

/// Beam-search / decoding parameters (configured through the command
/// decoder in hardware: `ConfigureBeamWidth` etc., Table 1).
#[derive(Debug, Clone, PartialEq)]
pub struct DecoderConfig {
    /// Score beam: hypotheses more than this below the best are pruned.
    pub beam: f32,
    /// Maximum live hypotheses (bounded by hypothesis-memory capacity).
    pub max_hyps: usize,
    /// Language-model score weight.
    pub lm_weight: f32,
    /// Additive penalty per emitted word (discourages over-segmentation).
    pub word_penalty: f32,
    /// Score bonus for staying in blank/repeat (0 = none).
    pub silence_bonus: f32,
}

impl Default for DecoderConfig {
    fn default() -> Self {
        DecoderConfig {
            beam: 14.0,
            max_hyps: AccelConfig::paper().hyp_capacity(),
            lm_weight: 1.2,
            word_penalty: -0.6,
            silence_bonus: 0.0,
        }
    }
}

impl DecoderConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.beam > 0.0, "beam must be positive");
        anyhow::ensure!(self.max_hyps >= 1, "need at least one hypothesis");
        anyhow::ensure!(self.lm_weight >= 0.0, "lm weight must be non-negative");
        Ok(())
    }
}

/// Dynamic-batching policy for the serving coordinator: how many ready
/// sessions a device batch may fuse, and how long the batcher may hold a
/// ready session waiting for lane-mates (measured in 10 ms feature
/// frames, the system's native time unit).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchConfig {
    /// Maximum sessions fused into one lane-batched step.
    pub max_batch: usize,
    /// Maximum wait for additional lanes, in feature frames (one frame =
    /// `hop_len` samples = 10 ms at 16 kHz). 0 = never wait.
    pub max_wait_frames: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        // 8 lanes × 8 frames: wait at most one decoding step (80 ms) to
        // fill a batch — latency bounded by one step, like the paper's
        // per-step device loop.
        BatchConfig { max_batch: 8, max_wait_frames: 8 }
    }
}

impl BatchConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.max_batch >= 1, "max_batch must be at least 1");
        Ok(())
    }

    /// The wait budget as wall-clock time for a given front-end geometry.
    pub fn max_wait(&self, model: &ModelConfig) -> std::time::Duration {
        std::time::Duration::from_secs_f64(
            self.max_wait_frames as f64 * model.hop_len as f64 / model.sample_rate as f64,
        )
    }
}

/// Multi-worker sharding policy for the serving coordinator: how many
/// device workers the server runs (each with its own `Batcher`, scratch
/// arenas and acoustic-backend handle over the shared model — the
/// paper's pool-of-general-purpose-cores shape lifted to the serving
/// layer) and when the router migrates still-unstarted sessions off a
/// hot shard.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardConfig {
    /// Device workers (shards). 1 = the classic single device thread.
    pub workers: usize,
    /// Open-session imbalance (hottest − coldest shard) at which the
    /// router migrates sessions — live, mid-utterance ones included
    /// (evict → snapshot → adopt → restore, transcript-bit-identical) —
    /// toward the cold shard. 0 disables rebalancing.
    pub rebalance_threshold: usize,
    /// Recovery-checkpoint cadence, in decoding steps: after a batch
    /// flush, every session that advanced at least this many steps since
    /// its last checkpoint ships a fresh
    /// [`SessionSnapshot`](crate::coordinator::SessionSnapshot) to the
    /// router, which holds it for dead-shard recovery and client
    /// resume. 1 = checkpoint at
    /// every flush (the reply a client receives is then always covered —
    /// its "last acknowledged snapshot"); larger values trade recovery
    /// rollback window for checkpoint bandwidth; 0 disables checkpoints
    /// (a dead shard's started sessions are then lost).
    pub checkpoint_interval: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        // One worker preserves the classic single-device-thread serving
        // loop; a threshold of 2 repairs any imbalance worth repairing
        // (diff/2 ≥ 1) as soon as it appears; checkpointing every flush
        // keeps acknowledged audio recoverable by default.
        ShardConfig { workers: 1, rebalance_threshold: 2, checkpoint_interval: 1 }
    }
}

impl ShardConfig {
    /// Reject configurations the router cannot run.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 1, "need at least one worker shard");
        anyhow::ensure!(
            self.workers <= 256,
            "workers capped at 256 (one OS thread per shard)"
        );
        Ok(())
    }
}

/// Resolve the artifacts directory: `$ASRPU_ARTIFACTS`, else `artifacts/`
/// relative to the working directory, else relative to the crate root
/// (for `cargo test` run from anywhere).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("ASRPU_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = Path::new("artifacts");
    if cwd.exists() {
        return cwd.to_path_buf();
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decoder_defaults_fit_hyp_memory() {
        let d = DecoderConfig::default();
        d.validate().unwrap();
        assert!(d.max_hyps <= AccelConfig::paper().hyp_capacity());
    }

    #[test]
    fn decoder_validation() {
        let mut d = DecoderConfig::default();
        d.beam = -1.0;
        assert!(d.validate().is_err());
    }

    #[test]
    fn shard_config_validation() {
        let s = ShardConfig::default();
        s.validate().unwrap();
        assert_eq!(s.workers, 1, "default must stay the single-device loop");
        assert_eq!(s.checkpoint_interval, 1, "acked audio recoverable by default");
        assert!(ShardConfig { workers: 0, ..s.clone() }.validate().is_err());
        assert!(ShardConfig { workers: 257, ..s.clone() }.validate().is_err());
        // Rebalancing and checkpointing may be disabled outright.
        ShardConfig {
            workers: 4,
            rebalance_threshold: 0,
            checkpoint_interval: 0,
        }
        .validate()
        .unwrap();
    }

    #[test]
    fn batch_config_wait_is_frame_scaled() {
        let b = BatchConfig::default();
        b.validate().unwrap();
        let m = ModelConfig::tiny_tds();
        // 8 frames × 10 ms = one decoding step.
        assert!((b.max_wait(&m).as_secs_f64() - 0.080).abs() < 1e-9);
        assert!(BatchConfig { max_batch: 0, ..b }.validate().is_err());
    }
}
