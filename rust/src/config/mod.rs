//! Configuration: accelerator hardware (Table 2), model topology (§4) and
//! decoder search parameters, plus artifact-directory resolution.

pub mod accel;
pub mod model;
pub mod pipeline;

pub use accel::AccelConfig;
pub use model::{Group, Layer, ModelConfig, Precision, PrecisionMap};
pub use pipeline::{PipelineDesc, StageDesc};

/// Re-exported so config consumers (serving introspection, the
/// simulator's host accounting) can name the host kernel ISA without
/// reaching into `am::gemm`.
pub use crate::am::gemm::dispatch::KernelIsa;

use std::path::{Path, PathBuf};

/// Beam-search / decoding parameters (configured through the command
/// decoder in hardware: `ConfigureBeamWidth` etc., Table 1).
#[derive(Debug, Clone, PartialEq)]
pub struct DecoderConfig {
    /// Score beam: hypotheses more than this below the best are pruned.
    pub beam: f32,
    /// Maximum live hypotheses (bounded by hypothesis-memory capacity).
    pub max_hyps: usize,
    /// Language-model score weight.
    pub lm_weight: f32,
    /// Additive penalty per emitted word (discourages over-segmentation).
    pub word_penalty: f32,
    /// Score bonus for staying in blank/repeat (0 = none).
    pub silence_bonus: f32,
}

impl Default for DecoderConfig {
    fn default() -> Self {
        DecoderConfig {
            beam: 14.0,
            max_hyps: AccelConfig::paper().hyp_capacity(),
            lm_weight: 1.2,
            word_penalty: -0.6,
            silence_bonus: 0.0,
        }
    }
}

impl DecoderConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.beam > 0.0, "beam must be positive");
        anyhow::ensure!(self.max_hyps >= 1, "need at least one hypothesis");
        anyhow::ensure!(self.lm_weight >= 0.0, "lm weight must be non-negative");
        Ok(())
    }
}

/// Dynamic-batching policy for the serving coordinator: how many ready
/// sessions a device batch may fuse, and how long the batcher may hold a
/// ready session waiting for lane-mates (measured in 10 ms feature
/// frames, the system's native time unit).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchConfig {
    /// Maximum sessions fused into one lane-batched step.
    pub max_batch: usize,
    /// Maximum wait for additional lanes, in feature frames (one frame =
    /// `hop_len` samples = 10 ms at 16 kHz). 0 = never wait.
    pub max_wait_frames: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        // 8 lanes × 8 frames: wait at most one decoding step (80 ms) to
        // fill a batch — latency bounded by one step, like the paper's
        // per-step device loop.
        BatchConfig { max_batch: 8, max_wait_frames: 8 }
    }
}

impl BatchConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.max_batch >= 1, "max_batch must be at least 1");
        Ok(())
    }

    /// The wait budget as wall-clock time for a given front-end geometry.
    pub fn max_wait(&self, model: &ModelConfig) -> std::time::Duration {
        std::time::Duration::from_secs_f64(
            self.max_wait_frames as f64 * model.hop_len as f64 / model.sample_rate as f64,
        )
    }
}

/// Multi-worker sharding policy for the serving coordinator: how many
/// device workers the server runs (each with its own `Batcher`, scratch
/// arenas and acoustic-backend handle over the shared model — the
/// paper's pool-of-general-purpose-cores shape lifted to the serving
/// layer) and when the router migrates still-unstarted sessions off a
/// hot shard.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardConfig {
    /// Device workers (shards). 1 = the classic single device thread.
    pub workers: usize,
    /// Open-session imbalance (hottest − coldest shard) at which the
    /// router migrates sessions — live, mid-utterance ones included
    /// (evict → snapshot → adopt → restore, transcript-bit-identical) —
    /// toward the cold shard. 0 disables rebalancing.
    pub rebalance_threshold: usize,
    /// Recovery-checkpoint cadence, in decoding steps: after a batch
    /// flush, every session that advanced at least this many steps since
    /// its last checkpoint ships a fresh
    /// [`SessionSnapshot`](crate::coordinator::SessionSnapshot) to the
    /// router, which holds it for dead-shard recovery and client
    /// resume. 1 = checkpoint at
    /// every flush (the reply a client receives is then always covered —
    /// its "last acknowledged snapshot"); larger values trade recovery
    /// rollback window for checkpoint bandwidth; 0 disables checkpoints
    /// (a dead shard's started sessions are then lost).
    pub checkpoint_interval: usize,
    /// Elasticity ceiling: the maximum *concurrently serving* workers
    /// the pool may grow to via the runtime `pool add` op. Retired
    /// (drained) workers do not count against it, so unlimited
    /// add/drain churn cycles stay legal. 0 = the pool is static at
    /// `workers` (elasticity off); otherwise must be ≥ `workers`.
    pub max_workers: usize,
    /// Wall-clock budget, in milliseconds, for a runtime `pool drain`
    /// to migrate every live session off the draining worker. Past the
    /// deadline the drain aborts and the worker reverts to serving
    /// (nothing is lost — migration is pipelined against live traffic
    /// either way).
    pub drain_deadline_ms: u64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        // One worker preserves the classic single-device-thread serving
        // loop; a threshold of 2 repairs any imbalance worth repairing
        // (diff/2 ≥ 1) as soon as it appears; checkpointing every flush
        // keeps acknowledged audio recoverable by default. Elasticity is
        // off (max_workers 0) — the pool behaves exactly like earlier
        // revisions unless a deployment opts in.
        ShardConfig {
            workers: 1,
            rebalance_threshold: 2,
            checkpoint_interval: 1,
            max_workers: 0,
            drain_deadline_ms: 5_000,
        }
    }
}

impl ShardConfig {
    /// Reject configurations the router cannot run.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 1, "need at least one worker shard");
        anyhow::ensure!(
            self.workers <= 256,
            "workers capped at 256 (one OS thread per shard)"
        );
        if self.max_workers != 0 {
            anyhow::ensure!(
                self.max_workers >= self.workers,
                "max_workers ({}) must be at least the initial worker count ({})",
                self.max_workers,
                self.workers
            );
            anyhow::ensure!(
                self.max_workers <= 256,
                "max_workers capped at 256 (one OS thread per shard)"
            );
        }
        anyhow::ensure!(
            self.drain_deadline_ms >= 1,
            "drain_deadline_ms must be at least 1"
        );
        Ok(())
    }

    /// The concurrent-worker ceiling the router enforces: `max_workers`
    /// when elasticity is on, else the static `workers` count.
    pub fn effective_max_workers(&self) -> usize {
        if self.max_workers == 0 {
            self.workers
        } else {
            self.max_workers
        }
    }
}

/// One rung of the graceful-degradation ladder: while a shard's decode
/// backlog sits at or above `enter_backlog_steps`, the worker serves
/// with this rung's (cheaper) search parameters instead of the
/// configured full-quality `DecoderConfig`.
///
/// Backlog is measured in *ready decoding steps* summed over the
/// shard's open sessions at flush time — a direct real-time-factor
/// headroom proxy: `backlog × step_seconds` is the audio time the shard
/// is behind by. Because the count is a pure function of the admitted
/// feed trace (workers drain their queue FIFO), the rung in effect at
/// every flush — and therefore every transcript — is deterministic for
/// a given request trace.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeLevel {
    /// Shard decode backlog (ready steps across open sessions) at or
    /// above which this rung engages. Rungs must be listed in strictly
    /// increasing threshold order; the deepest rung whose threshold is
    /// met wins.
    pub enter_backlog_steps: usize,
    /// Score beam served at this rung (narrower than the configured
    /// full-quality beam ⇒ cheaper pruning under load).
    pub beam: f32,
    /// Maximum live hypotheses at this rung.
    pub max_hyps: usize,
    /// Lane-batch budget cap at this rung: the batcher fuses at most
    /// `min(BatchConfig::max_batch, max_batch)` lanes. 0 = no extra cap.
    pub max_batch: usize,
}

impl DegradeLevel {
    /// Reject rungs the decoder cannot run.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.enter_backlog_steps >= 1, "degrade threshold must be at least 1");
        anyhow::ensure!(self.beam > 0.0, "degraded beam must be positive");
        anyhow::ensure!(self.max_hyps >= 1, "degraded search needs at least one hypothesis");
        Ok(())
    }
}

/// Overload policy for the serving coordinator: when to *refuse* new
/// sessions (admission control), when to *shed* queued-but-never-started
/// ones, how hard to *retry* a full shard queue before bouncing the
/// client, and the graceful-degradation ladder the workers step down
/// when their decode backlog grows.
///
/// The default policy is entirely **off** — unlimited admission, no
/// shedding, no retries, an empty ladder — preserving the exact serving
/// behaviour of earlier revisions unless a deployment opts in.
#[derive(Debug, Clone, PartialEq)]
pub struct OverloadPolicy {
    /// Maximum open sessions per shard before new `open` requests are
    /// rejected with `backpressure` (carrying [`retry_after_ms`]).
    /// 0 = unlimited (admission control off).
    ///
    /// [`retry_after_ms`]: OverloadPolicy::retry_after_ms
    pub admit_sessions_per_shard: usize,
    /// Client retry hint, in milliseconds, attached to every
    /// policy-driven `backpressure` rejection (admission refusals and
    /// full-queue bounces).
    pub retry_after_ms: u64,
    /// When a feed bounces off a saturated shard queue, shed that
    /// shard's oldest *never started* session (opened, zero audio fed)
    /// to make room — started sessions are never shed.
    pub shed_never_started: bool,
    /// Bounded retries for a shard queue that reports full before the
    /// client sees `backpressure`. 0 = bounce immediately (classic
    /// behaviour).
    pub route_retries: u32,
    /// Delay between route retries, in milliseconds (doubled per
    /// attempt). Retries are parked on a per-shard deferred-retry queue
    /// drained by the supervisor tick — the router thread never sleeps.
    pub route_backoff_ms: u64,
    /// How many shed session ids the router remembers so a returning
    /// client gets the dedicated `session_shed` notice (with its reopen
    /// hint) instead of a bare `unknown_session`. Oldest ids are
    /// evicted first (ids are monotone); evictions are surfaced in
    /// `stats` as `shed_evicted`. Must be ≥ 1.
    pub shed_memory: usize,
    /// Graceful-degradation ladder, strictly ascending by
    /// `enter_backlog_steps`. Empty = always serve full quality.
    pub levels: Vec<DegradeLevel>,
}

impl Default for OverloadPolicy {
    fn default() -> Self {
        // Everything off: earlier revisions' serving behaviour, bit for
        // bit. The 50 ms hint only appears once a limit is configured;
        // 4096 remembered shed ids matches the former hard constant.
        OverloadPolicy {
            admit_sessions_per_shard: 0,
            retry_after_ms: 50,
            shed_never_started: false,
            route_retries: 0,
            route_backoff_ms: 1,
            shed_memory: 4096,
            levels: Vec::new(),
        }
    }
}

impl OverloadPolicy {
    /// Reject ladders the workers cannot step down deterministically.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.shed_memory >= 1,
            "shed_memory must be at least 1 (the router needs somewhere \
             to remember a shed id)"
        );
        let mut prev = 0usize;
        for (i, lvl) in self.levels.iter().enumerate() {
            lvl.validate()?;
            anyhow::ensure!(
                lvl.enter_backlog_steps > prev,
                "degrade level {i} threshold {} must exceed the previous rung's {prev}",
                lvl.enter_backlog_steps
            );
            prev = lvl.enter_backlog_steps;
        }
        Ok(())
    }

    /// The rung in effect for a given decode backlog: 0 = full quality,
    /// `n` = `levels[n-1]`. Pure and hysteresis-free, so the level is
    /// reversible the moment pressure drains.
    pub fn level_for_backlog(&self, backlog_steps: usize) -> usize {
        self.levels.iter().take_while(|l| backlog_steps >= l.enter_backlog_steps).count()
    }

    /// The decoder parameters served at `level` (0 ⇒ `base` unchanged —
    /// full-quality parity after drain is exact, not approximate).
    pub fn decoder_at(&self, base: &DecoderConfig, level: usize) -> DecoderConfig {
        match level.checked_sub(1).and_then(|i| self.levels.get(i)) {
            None => base.clone(),
            Some(l) => DecoderConfig { beam: l.beam, max_hyps: l.max_hyps, ..base.clone() },
        }
    }

    /// The lane-batch cap at `level`, if that rung tightens one.
    pub fn batch_cap_at(&self, level: usize) -> Option<usize> {
        level
            .checked_sub(1)
            .and_then(|i| self.levels.get(i))
            .filter(|l| l.max_batch > 0)
            .map(|l| l.max_batch)
    }

    /// A two-rung reference ladder scaled to a batch geometry, used by
    /// the CLI's `--degrade` flag and the overload test-suites: at
    /// `base` backlog steps drop to a 2/3 beam and half the hypotheses,
    /// at `3 × base` halve the beam and quarter the hypotheses while
    /// also halving the lane budget.
    pub fn reference_ladder(base: usize, dec: &DecoderConfig, batch: &BatchConfig) -> Self {
        let base = base.max(1);
        OverloadPolicy {
            levels: vec![
                DegradeLevel {
                    enter_backlog_steps: base,
                    beam: dec.beam * 2.0 / 3.0,
                    max_hyps: (dec.max_hyps / 2).max(1),
                    max_batch: 0,
                },
                DegradeLevel {
                    enter_backlog_steps: base * 3,
                    beam: dec.beam / 2.0,
                    max_hyps: (dec.max_hyps / 4).max(1),
                    max_batch: (batch.max_batch / 2).max(1),
                },
            ],
            ..OverloadPolicy::default()
        }
    }
}

/// Resolve the artifacts directory: `$ASRPU_ARTIFACTS`, else `artifacts/`
/// relative to the working directory, else relative to the crate root
/// (for `cargo test` run from anywhere).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("ASRPU_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = Path::new("artifacts");
    if cwd.exists() {
        return cwd.to_path_buf();
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decoder_defaults_fit_hyp_memory() {
        let d = DecoderConfig::default();
        d.validate().unwrap();
        assert!(d.max_hyps <= AccelConfig::paper().hyp_capacity());
    }

    #[test]
    fn decoder_validation() {
        let mut d = DecoderConfig::default();
        d.beam = -1.0;
        assert!(d.validate().is_err());
    }

    #[test]
    fn shard_config_validation() {
        let s = ShardConfig::default();
        s.validate().unwrap();
        assert_eq!(s.workers, 1, "default must stay the single-device loop");
        assert_eq!(s.checkpoint_interval, 1, "acked audio recoverable by default");
        assert!(ShardConfig { workers: 0, ..s.clone() }.validate().is_err());
        assert!(ShardConfig { workers: 257, ..s.clone() }.validate().is_err());
        // Rebalancing and checkpointing may be disabled outright.
        ShardConfig {
            workers: 4,
            rebalance_threshold: 0,
            checkpoint_interval: 0,
            ..s.clone()
        }
        .validate()
        .unwrap();
        // Elasticity defaults off: the effective ceiling is the static
        // worker count.
        assert_eq!(s.max_workers, 0, "elasticity must default off");
        assert_eq!(s.effective_max_workers(), s.workers);
        let elastic = ShardConfig { workers: 2, max_workers: 8, ..s.clone() };
        elastic.validate().unwrap();
        assert_eq!(elastic.effective_max_workers(), 8);
        // A ceiling below the initial worker count is unservable, as is
        // one past the thread cap or a zero drain budget.
        assert!(ShardConfig { workers: 4, max_workers: 2, ..s.clone() }.validate().is_err());
        assert!(ShardConfig { max_workers: 257, ..s.clone() }.validate().is_err());
        assert!(ShardConfig { drain_deadline_ms: 0, ..s.clone() }.validate().is_err());
    }

    #[test]
    fn overload_policy_default_is_fully_off() {
        let p = OverloadPolicy::default();
        p.validate().unwrap();
        assert_eq!(p.admit_sessions_per_shard, 0, "admission control must default off");
        assert!(!p.shed_never_started);
        assert_eq!(p.route_retries, 0);
        assert_eq!(p.shed_memory, 4096, "default matches the former hard constant");
        assert!(OverloadPolicy { shed_memory: 0, ..p.clone() }.validate().is_err());
        assert!(p.levels.is_empty());
        // With an empty ladder every backlog maps to full quality.
        assert_eq!(p.level_for_backlog(0), 0);
        assert_eq!(p.level_for_backlog(usize::MAX), 0);
        let dec = DecoderConfig::default();
        assert_eq!(p.decoder_at(&dec, 0), dec);
        assert_eq!(p.batch_cap_at(0), None);
    }

    #[test]
    fn overload_ladder_levels_are_pure_threshold_steps() {
        let dec = DecoderConfig::default();
        let batch = BatchConfig::default();
        let p = OverloadPolicy::reference_ladder(10, &dec, &batch);
        p.validate().unwrap();
        assert_eq!(p.level_for_backlog(9), 0);
        assert_eq!(p.level_for_backlog(10), 1);
        assert_eq!(p.level_for_backlog(29), 1);
        assert_eq!(p.level_for_backlog(30), 2);
        // Level 0 is exactly the configured decoder — post-drain parity
        // is bit-exact by construction.
        assert_eq!(p.decoder_at(&dec, 0), dec);
        let l1 = p.decoder_at(&dec, 1);
        assert!(l1.beam < dec.beam && l1.max_hyps < dec.max_hyps);
        let l2 = p.decoder_at(&dec, 2);
        assert!(l2.beam < l1.beam && l2.max_hyps <= l1.max_hyps);
        l1.validate().unwrap();
        l2.validate().unwrap();
        assert_eq!(p.batch_cap_at(1), None);
        assert_eq!(p.batch_cap_at(2), Some(batch.max_batch / 2));
        // Past the deepest rung the deepest rung stays in effect.
        assert_eq!(p.level_for_backlog(10_000), 2);
    }

    #[test]
    fn overload_policy_validation_rejects_bad_ladders() {
        let dec = DecoderConfig::default();
        let batch = BatchConfig::default();
        let good = OverloadPolicy::reference_ladder(10, &dec, &batch);
        // Non-increasing thresholds.
        let mut p = good.clone();
        p.levels[1].enter_backlog_steps = 10;
        assert!(p.validate().is_err());
        // Unservable rung parameters.
        let mut p = good.clone();
        p.levels[0].beam = 0.0;
        assert!(p.validate().is_err());
        let mut p = good.clone();
        p.levels[0].max_hyps = 0;
        assert!(p.validate().is_err());
        let mut p = good;
        p.levels[0].enter_backlog_steps = 0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn batch_config_wait_is_frame_scaled() {
        let b = BatchConfig::default();
        b.validate().unwrap();
        let m = ModelConfig::tiny_tds();
        // 8 frames × 10 ms = one decoding step.
        assert!((b.max_wait(&m).as_secs_f64() - 0.080).abs() < 1e-9);
        assert!(BatchConfig { max_batch: 0, ..b }.validate().is_err());
    }
}
