//! ASRPU — a programmable accelerator for low-power automatic speech
//! recognition (Pinto, Arnau & González, 2022) as a running system.
//!
//! Two coupled halves share one configuration:
//!
//! * a **functional streaming ASR engine** — MFCC front-end ([`dsp`]), a
//!   time-depth-separable acoustic model served through the object-safe
//!   `AmBackend` trait (native f32 / int8 [`am`], or AOT-compiled XLA
//!   artifacts via [`runtime`]), and a CTC beam-search decoder with
//!   lexicon trie and n-gram LM ([`decoder`], [`lexicon`], [`lm`]),
//!   orchestrated by the streaming [`coordinator`] whose lane-batched
//!   execution core fuses concurrent sessions into shared device steps
//!   (bit-identical to scalar decoding per lane), and whose serving
//!   layer shards sessions across a pool of device workers over one
//!   `Arc`-shared model (bit-identical to the 1-worker engine —
//!   `tests/shard_parity.rs`). Per-session state is an explicit,
//!   serializable `SessionSnapshot`, so sessions migrate live between
//!   shards, survive worker crashes via recovery checkpoints, and
//!   resume after client reconnects (`tests/snapshot_parity.rs`).
//!   Engines are assembled through `Engine::builder()` and served over
//!   the v2 JSON-lines protocol (hello/config handshake, structured
//!   error codes, `resume`);
//! * a **cycle-approximate simulator of the ASRPU chip** ([`accel`]) with
//!   analytical area/power models ([`power`]) that regenerates every table
//!   and figure from the paper's evaluation ([`report`]). The simulator's
//!   kernel program is *derived* from the same stage description
//!   (`config::PipelineDesc`) the engine executes — one source of truth
//!   for the paper's "one program per decoder part".
//!
//! See DESIGN.md for the system inventory and the per-experiment index.
pub mod accel;
pub mod am;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod decoder;
pub mod dsp;
pub mod lexicon;
pub mod lm;
pub mod power;
pub mod report;
pub mod runtime;
pub mod synth;
pub mod util;
