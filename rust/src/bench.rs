//! Minimal benchmarking harness (criterion is not in the offline crate
//! set). Used by the `rust/benches/*.rs` targets (`harness = false`).
//!
//! Methodology: warm up, then run timed batches until both a minimum
//! wall-clock budget and a minimum iteration count are met; report
//! median / mean / p95 per-iteration time and derived throughput.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Where the bench targets write their machine-readable JSON results:
/// `$ASRPU_BENCH_DIR` when set and non-empty (CI points it at the
/// workspace so the files upload as artifacts), else the repository
/// root (one level above the crate), matching the committed
/// `BENCH_*.json` convention.
pub fn bench_dir() -> PathBuf {
    match std::env::var("ASRPU_BENCH_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => Path::new(env!("CARGO_MANIFEST_DIR")).join(".."),
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64()
    }
}

/// Benchmark runner with fixed budgets.
pub struct Bench {
    /// Minimum measured iterations.
    pub min_iters: u64,
    /// Minimum total measurement time.
    pub min_time: Duration,
    /// Warm-up time.
    pub warmup: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            min_iters: 20,
            min_time: Duration::from_millis(800),
            warmup: Duration::from_millis(150),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            min_iters: 5,
            min_time: Duration::from_millis(200),
            warmup: Duration::from_millis(50),
            ..Default::default()
        }
    }

    /// Time `f`; the closure should return something observable to keep
    /// the optimizer honest (its result is passed to `std::hint::black_box`).
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warm-up.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let m0 = Instant::now();
        while samples.len() < self.min_iters as usize || m0.elapsed() < self.min_time {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort();
        let median = samples[samples.len() / 2];
        let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len() as u64,
            median,
            mean,
            p95,
        };
        println!(
            "bench {name:<44} median {:>10.3?}  mean {:>10.3?}  p95 {:>10.3?}  ({} iters)",
            result.median, result.mean, result.p95, result.iters
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::quick();
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters >= 5);
        assert!(r.median.as_nanos() > 0);
    }

    #[test]
    fn bench_dir_defaults_to_repo_root() {
        // Without the env override the default is crate root/".." —
        // can't assert the env-var branch here without racing other
        // tests over the process environment.
        if std::env::var("ASRPU_BENCH_DIR").is_err() {
            assert!(bench_dir().ends_with(".."));
        }
    }

    #[test]
    fn results_accumulate() {
        let mut b = Bench::quick();
        b.run("a", || 1);
        b.run("b", || 2);
        assert_eq!(b.results().len(), 2);
    }
}
