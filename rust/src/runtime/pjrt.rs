//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them on the CPU PJRT client, and
//! execute them from the request path — python never runs at inference
//! time.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client plus the executables loaded on it.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// CPU PJRT client (the only backend in this environment; a TPU
    /// plugin would slot in here unchanged).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The underlying PJRT client (cheap `Rc` clone; buffers keep it
    /// alive).
    pub fn client_handle(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load an HLO-text file and compile it.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

impl Executable {
    /// Execute with host literals; the jax export wraps results in a
    /// tuple (`return_tuple=True`), which is decomposed here.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute with device buffers (hot path: no host round-trips for
    /// the inputs). Returns the raw output buffers — either one tuple
    /// buffer or one buffer per result leaf, depending on the PJRT
    /// plugin's untupling behaviour; callers handle both.
    pub fn run_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut result = self
            .exe
            .execute_b(inputs)
            .with_context(|| format!("executing {} (buffers)", self.name))?;
        anyhow::ensure!(!result.is_empty(), "no execution results");
        Ok(std::mem::take(&mut result[0]))
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(
        n as usize == data.len(),
        "literal shape {dims:?} != data len {}",
        data.len()
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Zero-filled f32 literal.
pub fn literal_zeros(dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    literal_f32(&vec![0.0; n as usize], dims)
}

/// Extract f32 data from a literal.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
