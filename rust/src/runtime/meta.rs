//! `artifacts/meta.json` — the contract between the python build path
//! and the Rust runtime: model geometry, parameter order/shapes,
//! streaming-state shapes and training metrics.

use crate::config::{Group, ModelConfig};
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Parsed artifact metadata.
#[derive(Debug, Clone)]
pub struct Meta {
    pub model: ModelConfig,
    /// Parameter (name, shape) in the exact order the exported step
    /// function expects them.
    pub params: Vec<(String, Vec<usize>)>,
    /// Conv-history state shapes, in conv-layer order.
    pub states: Vec<Vec<usize>>,
    pub model_hlo: String,
    pub mfcc_hlo: String,
    pub weights_file: String,
    pub frame_acc: f64,
    pub token_seq_acc: f64,
}

impl Meta {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing meta.json")?;
        let m = j.get("model").context("meta.json missing 'model'")?;
        let req_num = |path: &str| -> Result<usize> {
            m.get(path)
                .and_then(Json::as_usize)
                .with_context(|| format!("meta.json model.{path} missing"))
        };
        let groups = m
            .get("groups")
            .and_then(Json::as_arr)
            .context("meta.json missing model.groups")?
            .iter()
            .map(|g| {
                Ok(Group {
                    channels: g.get("channels").and_then(Json::as_usize).context("channels")?,
                    blocks: g.get("blocks").and_then(Json::as_usize).context("blocks")?,
                    kw: g.get("kw").and_then(Json::as_usize).context("kw")?,
                    entry_stride: g
                        .get("entry_stride")
                        .and_then(Json::as_usize)
                        .context("entry_stride")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let model = ModelConfig {
            name: m
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("artifact-model")
                .to_string(),
            sample_rate: req_num("sample_rate")?,
            win_len: req_num("win_len")?,
            hop_len: req_num("hop_len")?,
            n_mels: req_num("n_mels")?,
            step_len: req_num("step_len")?,
            groups,
            final_conv_kw: m.get("final_conv_kw").and_then(Json::as_usize),
            tokens: req_num("tokens")?,
            precision: crate::config::Precision::F32,
        };
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .context("meta.json missing 'params'")?
            .iter()
            .map(|p| {
                let name = p
                    .get("name")
                    .and_then(Json::as_str)
                    .context("param name")?
                    .to_string();
                let shape = p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("param shape")?
                    .iter()
                    .map(|d| d.as_usize().context("param dim"))
                    .collect::<Result<Vec<_>>>()?;
                Ok((name, shape))
            })
            .collect::<Result<Vec<_>>>()?;
        let states = j
            .get("states")
            .and_then(Json::as_arr)
            .context("meta.json missing 'states'")?
            .iter()
            .map(|s| {
                s.as_arr()
                    .context("state shape")?
                    .iter()
                    .map(|d| d.as_usize().context("state dim"))
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        let arts = j.get("artifacts").context("meta.json missing 'artifacts'")?;
        let art = |k: &str| -> Result<String> {
            Ok(arts
                .get(k)
                .and_then(Json::as_str)
                .with_context(|| format!("artifacts.{k}"))?
                .to_string())
        };
        let meta = Meta {
            model,
            params,
            states,
            model_hlo: art("model_hlo")?,
            mfcc_hlo: art("mfcc_hlo")?,
            weights_file: art("weights")?,
            frame_acc: j
                .get("training.frame_acc")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            token_seq_acc: j
                .get("training.token_seq_acc")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
        };
        meta.validate()?;
        Ok(meta)
    }

    fn validate(&self) -> Result<()> {
        // The state count must equal the number of conv layers, and the
        // parameter list must cover every layer (2 tensors each).
        let layers = self.model.layers();
        let n_conv = layers
            .iter()
            .filter(|l| matches!(l, crate::config::Layer::Conv { .. }))
            .count();
        ensure!(
            self.states.len() == n_conv,
            "meta.json: {} states but model has {} conv layers",
            self.states.len(),
            n_conv
        );
        ensure!(
            self.params.len() == 2 * layers.len(),
            "meta.json: {} params but model has {} layers",
            self.params.len(),
            layers.len()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A meta.json for the tiny model, matching python's export format.
    pub fn tiny_meta_json() -> String {
        let cfg = ModelConfig::tiny_tds();
        let mut params = String::new();
        for layer in cfg.layers() {
            let name = layer.name();
            use crate::config::Layer;
            let (a, ashape, b, bshape) = match &layer {
                Layer::Conv { in_ch, out_ch, kw, .. } => (
                    format!("{name}.w"),
                    vec![*out_ch, *in_ch, *kw],
                    format!("{name}.b"),
                    vec![*out_ch],
                ),
                Layer::Fc { in_dim, out_dim, .. } => (
                    format!("{name}.w"),
                    vec![*out_dim, *in_dim],
                    format!("{name}.b"),
                    vec![*out_dim],
                ),
                Layer::LayerNorm { dim, .. } => {
                    (format!("{name}.g"), vec![*dim], format!("{name}.b"), vec![*dim])
                }
            };
            let fmt = |s: &[usize]| {
                s.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
            };
            params.push_str(&format!(
                r#"{{"name":"{a}","shape":[{}]}},{{"name":"{b}","shape":[{}]}},"#,
                fmt(&ashape),
                fmt(&bshape)
            ));
        }
        params.pop();
        // State shapes in conv order.
        let mut states = String::new();
        let mut in_dim = cfg.n_mels;
        for layer in cfg.layers() {
            use crate::config::Layer;
            match &layer {
                Layer::Conv { out_ch, kw, w, .. } => {
                    states.push_str(&format!("[{},{}],", kw - 1, in_dim));
                    in_dim = out_ch * w;
                }
                Layer::Fc { out_dim, .. } => in_dim = *out_dim,
                _ => {}
            }
        }
        states.pop();
        format!(
            r#"{{"model":{{"name":"tiny-tds","sample_rate":16000,"win_len":400,"hop_len":160,
"n_mels":40,"step_len":1280,
"groups":[{{"channels":2,"blocks":1,"kw":5,"entry_stride":2}},
          {{"channels":3,"blocks":2,"kw":5,"entry_stride":1}}],
"final_conv_kw":null,"tokens":27}},
"params":[{params}],
"states":[{states}],
"artifacts":{{"model_hlo":"model_step.hlo.txt","mfcc_hlo":"mfcc.hlo.txt","weights":"weights.bin"}},
"training":{{"frame_acc":0.99,"token_seq_acc":0.97}}}}"#
        )
    }

    #[test]
    fn parses_tiny_meta() {
        let meta = Meta::parse(&tiny_meta_json()).unwrap();
        assert_eq!(meta.model, ModelConfig::tiny_tds());
        assert_eq!(meta.states.len(), 5, "5 conv layers");
        assert_eq!(meta.params.len(), 2 * meta.model.layers().len());
        assert!((meta.frame_acc - 0.99).abs() < 1e-9);
    }

    #[test]
    fn rejects_inconsistent_states() {
        let text = tiny_meta_json().replace(r#""states":[[4,40],"#, r#""states":["#);
        assert!(Meta::parse(&text).is_err());
    }

    #[test]
    fn rejects_missing_sections() {
        assert!(Meta::parse("{}").is_err());
        let text = tiny_meta_json().replace("\"params\"", "\"paramsX\"");
        assert!(Meta::parse(&text).is_err());
    }
}
