//! The artifact-backed acoustic model: MFCC + streaming TDS step, both
//! executed as AOT-compiled XLA computations through PJRT — the
//! functional analogue of ASRPU's acoustic-scoring phase where the
//! "kernels" were compiled ahead of time from JAX/Pallas instead of
//! hand-written RISC-V programs.
//!
//! Hot-path design (§Perf, EXPERIMENTS.md): weights are uploaded to
//! device buffers **once** at load; streaming conv states stay as device
//! buffers across steps whenever the PJRT execute path returns untupled
//! outputs (it does on the CPU plugin); only the per-step features go up
//! and the per-step log-probs come down. This removed the per-step
//! literal round-trip of every weight tensor (~7× step-time reduction).

use anyhow::{ensure, Context, Result};
use std::path::Path;

use crate::util::tensor_io::{Tensor, TensorFile};

use super::meta::Meta;
use super::{literal_f32, literal_to_f32, Executable, Runtime};

/// Streaming state: one device buffer per conv layer.
pub struct XlaState {
    states: Vec<xla::PjRtBuffer>,
}

/// The compiled model + device-resident weights.
pub struct XlaAm {
    pub meta: Meta,
    client: xla::PjRtClient,
    mfcc_exe: Executable,
    step_exe: Executable,
    /// Weight buffers in export parameter order (uploaded once).
    weights: Vec<xla::PjRtBuffer>,
}

impl XlaAm {
    /// Load everything from an artifacts directory.
    pub fn load(runtime: &Runtime, dir: &Path) -> Result<Self> {
        let meta = Meta::load(dir)?;
        let mfcc_exe = runtime.load_hlo(&dir.join(&meta.mfcc_hlo))?;
        let step_exe = runtime.load_hlo(&dir.join(&meta.model_hlo))?;
        let client = runtime.client_handle().clone();
        let tf = TensorFile::load(&dir.join(&meta.weights_file))?;
        let mut weights = Vec::with_capacity(meta.params.len());
        for (name, shape) in &meta.params {
            let t = tf.require(name)?;
            ensure!(
                &t.dims == shape,
                "weights.bin '{name}' dims {:?} != meta {shape:?}",
                t.dims
            );
            weights.push(
                client
                    .buffer_from_host_buffer::<f32>(t.as_f32()?, shape, None)
                    .with_context(|| format!("uploading weight '{name}'"))?,
            );
        }
        Ok(XlaAm { meta, client, mfcc_exe, step_exe, weights })
    }

    /// Fresh streaming state (zero conv histories) as device buffers.
    pub fn state(&self) -> Result<XlaState> {
        let states = self
            .meta
            .states
            .iter()
            .map(|s| {
                let n: usize = s.iter().product();
                self.client
                    .buffer_from_host_buffer::<f32>(&vec![0.0; n], s, None)
                    .context("allocating state buffer")
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(XlaState { states })
    }

    /// Download the device-resident conv states into host `state{i}`
    /// tensors — the XLA half of a session snapshot. The device buffers
    /// stay valid; this is a read-only copy.
    pub fn snapshot_state(&self, state: &XlaState, tf: &mut TensorFile) -> Result<()> {
        ensure!(
            state.states.len() == self.meta.states.len(),
            "state has {} buffers, meta declares {}",
            state.states.len(),
            self.meta.states.len()
        );
        for (i, (buf, shape)) in state.states.iter().zip(&self.meta.states).enumerate() {
            let lit = buf
                .to_literal_sync()
                .with_context(|| format!("downloading state buffer {i}"))?;
            let data = literal_to_f32(&lit)?;
            ensure!(
                data.len() == shape.iter().product::<usize>(),
                "state buffer {i}: {} elements, shape {shape:?}",
                data.len()
            );
            tf.push(Tensor::f32(format!("state{i}"), shape.clone(), data));
        }
        Ok(())
    }

    /// Rebuild a streaming state from host `state{i}` tensors by
    /// uploading each onto the device — the restore half of a session
    /// snapshot (live migration / resume for the artifact backend).
    pub fn restore_state(&self, tf: &TensorFile) -> Result<XlaState> {
        let mut states = Vec::with_capacity(self.meta.states.len());
        for (i, shape) in self.meta.states.iter().enumerate() {
            let t = tf.require(&format!("state{i}"))?;
            ensure!(
                &t.dims == shape,
                "state tensor 'state{i}': dims {:?}, expected {shape:?}",
                t.dims
            );
            states.push(
                self.client
                    .buffer_from_host_buffer::<f32>(t.as_f32()?, shape, None)
                    .with_context(|| format!("uploading state buffer {i}"))?,
            );
        }
        Ok(XlaState { states })
    }

    /// Feature extraction for one decoding step:
    /// `samples_per_step` samples → `frames_per_step × n_mels`.
    pub fn mfcc(&self, samples: &[f32]) -> Result<Vec<f32>> {
        let m = &self.meta.model;
        ensure!(
            samples.len() == m.samples_per_step(),
            "mfcc expects {} samples, got {}",
            m.samples_per_step(),
            samples.len()
        );
        let input = literal_f32(samples, &[samples.len() as i64])?;
        let out = self.mfcc_exe.run(&[input])?;
        literal_to_f32(&out[0])
    }

    /// [`Self::step`] appending the log-probs to a caller-owned buffer —
    /// the backend trait's arena-friendly entry point: the engine stages
    /// lane-major batched output through one reused `out` vector. (The
    /// PJRT execute path itself still allocates host/device buffers per
    /// step; only the engine-side staging is arena-backed.)
    pub fn step_into(
        &self,
        state: &mut XlaState,
        feats: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let logits = self.step(state, feats)?;
        out.extend_from_slice(&logits);
        Ok(())
    }

    /// One acoustic-scoring step: features in, log-probs out, conv state
    /// advanced in place (device-resident).
    pub fn step(&self, state: &mut XlaState, feats: &[f32]) -> Result<Vec<f32>> {
        let m = &self.meta.model;
        ensure!(
            feats.len() == m.frames_per_step() * m.n_mels,
            "step expects {}x{} features",
            m.frames_per_step(),
            m.n_mels
        );
        let feats_buf = self
            .client
            .buffer_from_host_buffer::<f32>(feats, &[m.frames_per_step(), m.n_mels], None)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(1 + state.states.len() + self.weights.len());
        args.push(&feats_buf);
        args.extend(state.states.iter());
        args.extend(self.weights.iter());
        let mut result = self
            .step_exe
            .run_buffers(&args)
            .context("model step (execute_b)")?;
        let n_states = state.states.len();
        if result.len() == 1 + n_states {
            // Untupled outputs: keep the new states on device.
            let logits_lit = result[0].to_literal_sync()?;
            let logits = literal_to_f32(&logits_lit)?;
            ensure!(logits.len() == m.vectors_per_step() * m.tokens);
            state.states = result.split_off(1);
            Ok(logits)
        } else {
            // Tupled single output: decompose on host, re-upload states.
            ensure!(result.len() == 1, "unexpected output arity {}", result.len());
            let tuple = result[0].to_literal_sync()?.to_tuple()?;
            ensure!(tuple.len() == 1 + n_states);
            let logits = literal_to_f32(&tuple[0])?;
            ensure!(logits.len() == m.vectors_per_step() * m.tokens);
            let mut new_states = Vec::with_capacity(n_states);
            for (lit, shape) in tuple[1..].iter().zip(&self.meta.states) {
                let data = literal_to_f32(lit)?;
                new_states.push(self.client.buffer_from_host_buffer::<f32>(
                    &data,
                    shape,
                    None,
                )?);
            }
            state.states = new_states;
            Ok(logits)
        }
    }
}
