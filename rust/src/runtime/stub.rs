//! Stub runtime compiled when the `xla` feature is off (the offline
//! default): same types and signatures as the PJRT-backed implementation,
//! but every entry point reports that the artifact runtime is
//! unavailable. Callers already gate on `artifacts/meta.json` before
//! touching the runtime, so in practice these errors only surface when
//! artifacts exist but the crate was built without PJRT support.

use anyhow::{bail, Result};
use std::path::Path;

use super::meta::Meta;
use crate::util::tensor_io::TensorFile;

const NO_XLA: &str =
    "built without the `xla` feature: the PJRT artifact runtime is unavailable \
     (use the native backend, or rebuild with --features xla)";

/// Stub PJRT client handle.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        bail!(NO_XLA)
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }
}

/// Stub streaming state.
pub struct XlaState {
    _private: (),
}

/// Stub artifact-backed acoustic model.
pub struct XlaAm {
    pub meta: Meta,
}

impl XlaAm {
    pub fn load(_runtime: &Runtime, _dir: &Path) -> Result<Self> {
        bail!(NO_XLA)
    }

    pub fn state(&self) -> Result<XlaState> {
        bail!(NO_XLA)
    }

    pub fn mfcc(&self, _samples: &[f32]) -> Result<Vec<f32>> {
        bail!(NO_XLA)
    }

    pub fn step(&self, _state: &mut XlaState, _feats: &[f32]) -> Result<Vec<f32>> {
        bail!(NO_XLA)
    }

    pub fn step_into(
        &self,
        _state: &mut XlaState,
        _feats: &[f32],
        _out: &mut Vec<f32>,
    ) -> Result<()> {
        bail!(NO_XLA)
    }

    pub fn snapshot_state(&self, _state: &XlaState, _tf: &mut TensorFile) -> Result<()> {
        bail!(NO_XLA)
    }

    pub fn restore_state(&self, _tf: &TensorFile) -> Result<XlaState> {
        bail!(NO_XLA)
    }
}
