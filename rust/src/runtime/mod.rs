//! Artifact runtime: metadata parsing ([`meta`]) plus the PJRT-backed
//! acoustic model ([`xla_am`]) when the crate is built with the `xla`
//! feature. Without it (the offline default) a stub with the same API
//! surface is compiled instead: `Runtime::cpu()` and `XlaAm::load()`
//! return errors and the engine falls back to the native backend, so
//! every caller that gates on `artifacts/meta.json` keeps working.

pub mod meta;

pub use meta::Meta;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub mod xla_am;
#[cfg(feature = "xla")]
pub use pjrt::{literal_f32, literal_to_f32, literal_zeros, Executable, Runtime};
#[cfg(feature = "xla")]
pub use xla_am::XlaAm;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{Runtime, XlaAm};
#[cfg(not(feature = "xla"))]
pub mod xla_am {
    //! Stub surface mirroring the PJRT-backed module.
    pub use super::stub::{XlaAm, XlaState};
}
