//! Mel filterbank and DCT-II — the §2.1 front-end steps between the FFT
//! and the feature frame. The construction here is mirrored exactly by
//! `python/compile/features.py` so the trained model and the native
//! engine consume identical features (tests assert allclose).

/// HTK mel scale.
pub fn hz_to_mel(hz: f64) -> f64 {
    2595.0 * (1.0 + hz / 700.0).log10()
}

pub fn mel_to_hz(mel: f64) -> f64 {
    700.0 * (10f64.powf(mel / 2595.0) - 1.0)
}

/// Triangular mel filterbank over a one-sided power spectrum.
#[derive(Debug, Clone)]
pub struct MelBank {
    pub n_mels: usize,
    pub n_bins: usize,
    /// Dense (n_mels × n_bins) filter matrix — kept because the JAX
    /// mirror is dense and tests compare row-for-row.
    pub weights: Vec<f32>,
    /// Sparse view: per filter, (first nonzero bin, nonzero weights).
    /// Triangular filters touch ~2·n_bins entries total vs
    /// n_mels·n_bins dense — the §Perf hot path uses this.
    sparse: Vec<(usize, Vec<f32>)>,
}

impl MelBank {
    /// `n_fft`-point analysis at `sample_rate`, `n_mels` filters spanning
    /// `[fmin, fmax]` Hz.
    pub fn new(sample_rate: usize, n_fft: usize, n_mels: usize, fmin: f64, fmax: f64) -> Self {
        assert!(fmax <= sample_rate as f64 / 2.0, "fmax above Nyquist");
        assert!(fmin < fmax);
        let n_bins = n_fft / 2 + 1;
        // n_mels + 2 equally spaced points on the mel axis.
        let lo = hz_to_mel(fmin);
        let hi = hz_to_mel(fmax);
        let pts: Vec<f64> = (0..n_mels + 2)
            .map(|i| mel_to_hz(lo + (hi - lo) * i as f64 / (n_mels + 1) as f64))
            .collect();
        let bin_hz = sample_rate as f64 / n_fft as f64;
        let mut weights = vec![0.0f32; n_mels * n_bins];
        for m in 0..n_mels {
            let (f_lo, f_c, f_hi) = (pts[m], pts[m + 1], pts[m + 2]);
            for b in 0..n_bins {
                let f = b as f64 * bin_hz;
                let w = if f <= f_lo || f >= f_hi {
                    0.0
                } else if f <= f_c {
                    (f - f_lo) / (f_c - f_lo)
                } else {
                    (f_hi - f) / (f_hi - f_c)
                };
                weights[m * n_bins + b] = w as f32;
            }
        }
        let sparse = (0..n_mels)
            .map(|m| {
                let row = &weights[m * n_bins..(m + 1) * n_bins];
                let first = row.iter().position(|&w| w != 0.0).unwrap_or(0);
                let last = row.iter().rposition(|&w| w != 0.0).unwrap_or(0);
                (first, row[first..=last].to_vec())
            })
            .collect();
        MelBank { n_mels, n_bins, weights, sparse }
    }

    /// Apply the bank: `out[m] = Σ_b w[m,b] · ps[b]` (sparse inner loop).
    pub fn apply(&self, power_spectrum: &[f32], out: &mut Vec<f32>) {
        assert_eq!(power_spectrum.len(), self.n_bins);
        out.clear();
        for (first, ws) in &self.sparse {
            let mut acc = 0.0f32;
            for (w, p) in ws.iter().zip(&power_spectrum[*first..]) {
                acc += w * p;
            }
            out.push(acc);
        }
    }
}

/// Orthonormal DCT-II matrix (n × n), row-major: `out = D · in`.
#[derive(Debug, Clone)]
pub struct Dct {
    pub n: usize,
    pub matrix: Vec<f32>,
}

impl Dct {
    pub fn new(n: usize) -> Self {
        let mut matrix = vec![0.0f32; n * n];
        let norm0 = (1.0 / n as f64).sqrt();
        let norm = (2.0 / n as f64).sqrt();
        for k in 0..n {
            for t in 0..n {
                let v = (std::f64::consts::PI / n as f64 * (t as f64 + 0.5) * k as f64).cos();
                matrix[k * n + t] = (v * if k == 0 { norm0 } else { norm }) as f32;
            }
        }
        Dct { n, matrix }
    }

    pub fn apply(&self, input: &[f32], out: &mut Vec<f32>) {
        assert_eq!(input.len(), self.n);
        out.clear();
        for k in 0..self.n {
            let row = &self.matrix[k * self.n..(k + 1) * self.n];
            out.push(row.iter().zip(input).map(|(a, b)| a * b).sum());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn mel_scale_roundtrip() {
        for hz in [20.0, 440.0, 1000.0, 7600.0] {
            assert!((mel_to_hz(hz_to_mel(hz)) - hz).abs() < 1e-6);
        }
        assert!((hz_to_mel(1000.0) - 999.985).abs() < 0.1, "1 kHz ≈ 1000 mel");
    }

    #[test]
    fn filters_partition_reasonably() {
        let bank = MelBank::new(16_000, 512, 80, 20.0, 7600.0);
        // Each filter is non-empty and peaks ≤ 1.
        for m in 0..bank.n_mels {
            let row = &bank.weights[m * bank.n_bins..(m + 1) * bank.n_bins];
            let peak = row.iter().cloned().fold(0.0f32, f32::max);
            assert!(peak > 0.0, "filter {m} empty");
            assert!(peak <= 1.0 + 1e-6);
        }
        // Flat spectrum maps to strictly positive mel energies.
        let ps = vec![1.0f32; bank.n_bins];
        let mut mel = Vec::new();
        bank.apply(&ps, &mut mel);
        assert!(mel.iter().all(|&m| m > 0.0));
    }

    #[test]
    fn tone_lands_in_matching_filter() {
        let bank = MelBank::new(16_000, 512, 40, 20.0, 7600.0);
        // Power concentrated at bin for 1 kHz: bin = 1000/ (16000/512) = 32.
        let mut ps = vec![0.0f32; bank.n_bins];
        ps[32] = 1.0;
        let mut mel = Vec::new();
        bank.apply(&ps, &mut mel);
        let peak = mel
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        // 1 kHz ≈ 1000 mel; filters span 20..7600 Hz ≈ 31.6..2840 mel.
        // Expected filter ≈ (1000-31.6)/(2840-31.6)*41 ≈ 14.
        assert!((12..=16).contains(&peak), "peak filter {peak}");
    }

    #[test]
    fn dct_is_orthonormal() {
        let d = Dct::new(32);
        // D·Dᵀ = I.
        for i in 0..d.n {
            for j in 0..d.n {
                let dot: f32 = (0..d.n)
                    .map(|t| d.matrix[i * d.n + t] * d.matrix[j * d.n + t])
                    .sum();
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-5, "({i},{j}) = {dot}");
            }
        }
    }

    #[test]
    fn dct_preserves_energy_property() {
        prop::check("dct-parseval", 25, |g| {
            let d = Dct::new(40);
            let x = g.vec_of(40, |r| r.uniform(-2.0, 2.0));
            let mut y = Vec::new();
            d.apply(&x, &mut y);
            let ex: f32 = x.iter().map(|v| v * v).sum();
            let ey: f32 = y.iter().map(|v| v * v).sum();
            crate::prop_assert!((ex - ey).abs() / (1.0 + ex) < 1e-4, "ex={ex} ey={ey}");
            Ok(())
        });
    }
}
