//! The complete MFCC front-end (Fig. 3): framing → pre-emphasis →
//! Hamming window → FFT power spectrum → mel filterbank → log → DCT-II.
//!
//! On ASRPU this is kernel 0 of the acoustic scoring phase, one thread per
//! output frame (§4.2). Here it is the native implementation; the same
//! algorithm, with identical constants, is implemented in JAX by
//! `python/compile/features.py` and exported as `artifacts/mfcc.hlo.txt`.
//! An integration test asserts the two agree to ~1e-3.

use super::fft::FftPlan;
use super::mel::{Dct, MelBank};

/// Pre-emphasis coefficient (applied within each frame, Kaldi-style:
/// `y[0] = x[0] - COEF·x[0]`, keeping the transform purely per-frame so
/// the JAX mirror is stateless).
pub const PREEMPH: f32 = 0.97;
/// Hamming window parameters.
pub const HAMMING_A: f32 = 0.54;
pub const HAMMING_B: f32 = 0.46;
/// Mel filterbank frequency range.
pub const FMIN_HZ: f64 = 20.0;
pub const FMAX_HZ: f64 = 7600.0;
/// Floor applied before the log to avoid -inf on silence.
pub const LOG_FLOOR: f32 = 1e-10;

/// Reusable scratch buffers for allocation-free frame extraction.
#[derive(Debug, Default, Clone)]
pub struct Scratch {
    buf: Vec<f32>,
    re: Vec<f32>,
    im: Vec<f32>,
    ps: Vec<f32>,
    mel: Vec<f32>,
}

/// MFCC extractor configuration + precomputed plans.
#[derive(Debug, Clone)]
pub struct Mfcc {
    pub win_len: usize,
    pub hop_len: usize,
    pub n_mels: usize,
    pub n_fft: usize,
    window: Vec<f32>,
    fft: FftPlan,
    bank: MelBank,
    dct: Dct,
}

impl Mfcc {
    pub fn new(sample_rate: usize, win_len: usize, hop_len: usize, n_mels: usize) -> Self {
        let n_fft = win_len.next_power_of_two();
        let window: Vec<f32> = (0..win_len)
            .map(|n| {
                HAMMING_A
                    - HAMMING_B
                        * ((2.0 * std::f64::consts::PI * n as f64 / (win_len - 1) as f64).cos()
                            as f32)
            })
            .collect();
        Mfcc {
            win_len,
            hop_len,
            n_mels,
            n_fft,
            window,
            fft: FftPlan::new(n_fft),
            bank: MelBank::new(sample_rate, n_fft, n_mels, FMIN_HZ, FMAX_HZ),
            dct: Dct::new(n_mels),
        }
    }

    /// Build the extractor matching a model's front-end geometry.
    pub fn for_model(m: &crate::config::ModelConfig) -> Self {
        Mfcc::new(m.sample_rate, m.win_len, m.hop_len, m.n_mels)
    }

    /// Number of complete frames extractable from `n` samples.
    pub fn frames_in(&self, n: usize) -> usize {
        if n < self.win_len {
            0
        } else {
            (n - self.win_len) / self.hop_len + 1
        }
    }

    /// Extract one feature frame from `samples[start..start+win_len]`.
    pub fn frame(&self, samples: &[f32], start: usize, out: &mut Vec<f32>) {
        let mut scratch = Scratch::default();
        self.frame_scratch(samples, start, &mut scratch, out);
    }

    /// Allocation-free per-frame extraction with reused scratch buffers
    /// (§Perf: avoids 5 allocations per frame on the streaming path).
    pub fn frame_scratch(
        &self,
        samples: &[f32],
        start: usize,
        s: &mut Scratch,
        out: &mut Vec<f32>,
    ) {
        let win = &samples[start..start + self.win_len];
        // Pre-emphasis + window, fused.
        s.buf.clear();
        s.buf.resize(self.win_len, 0.0);
        s.buf[0] = win[0] - PREEMPH * win[0];
        for n in 1..self.win_len {
            s.buf[n] = win[n] - PREEMPH * win[n - 1];
        }
        for (b, w) in s.buf.iter_mut().zip(&self.window) {
            *b *= w;
        }
        self.fft
            .power_spectrum_scratch(&s.buf, &mut s.re, &mut s.im, &mut s.ps);
        self.bank.apply(&s.ps, &mut s.mel);
        for m in s.mel.iter_mut() {
            *m = m.max(LOG_FLOOR).ln();
        }
        self.dct.apply(&s.mel, out);
    }

    /// Extract all complete frames; returns a (frames × n_mels) row-major
    /// matrix.
    pub fn extract(&self, samples: &[f32]) -> Vec<f32> {
        let mut feats = Vec::with_capacity(self.frames_in(samples.len()) * self.n_mels);
        let mut frame = Vec::with_capacity(self.n_mels);
        let mut scratch = Scratch::default();
        self.extract_into(samples, &mut scratch, &mut frame, &mut feats);
        feats
    }

    /// Allocation-free [`Self::extract`]: **appends** all complete frames
    /// to `out` through caller-owned scratch buffers (the engine's
    /// batched step gathers several lanes into one `out` this way).
    pub fn extract_into(
        &self,
        samples: &[f32],
        scratch: &mut Scratch,
        frame: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) {
        let n_frames = self.frames_in(samples.len());
        for f in 0..n_frames {
            self.frame_scratch(samples, f * self.hop_len, scratch, frame);
            out.extend_from_slice(frame);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tone(freq: f64, n: usize, rate: f64) -> Vec<f32> {
        (0..n)
            .map(|t| (2.0 * std::f64::consts::PI * freq * t as f64 / rate).sin() as f32 * 0.5)
            .collect()
    }

    #[test]
    fn frame_count_geometry() {
        let m = Mfcc::new(16_000, 400, 160, 40);
        assert_eq!(m.frames_in(399), 0);
        assert_eq!(m.frames_in(400), 1);
        assert_eq!(m.frames_in(1520), 8, "one decoding step = 8 frames");
        assert_eq!(m.n_fft, 512);
    }

    #[test]
    fn output_shape() {
        let m = Mfcc::new(16_000, 400, 160, 40);
        let feats = m.extract(&tone(440.0, 1520, 16_000.0));
        assert_eq!(feats.len(), 8 * 40);
        assert!(feats.iter().all(|f| f.is_finite()));
    }

    #[test]
    fn distinct_tones_produce_distinct_features() {
        let m = Mfcc::new(16_000, 400, 160, 40);
        let a = m.extract(&tone(300.0, 400, 16_000.0));
        let b = m.extract(&tone(2000.0, 400, 16_000.0));
        let dist: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt();
        assert!(dist > 1.0, "tones not separated: {dist}");
    }

    #[test]
    fn silence_is_floor_stable() {
        let m = Mfcc::new(16_000, 400, 160, 40);
        let feats = m.extract(&vec![0.0f32; 400]);
        assert!(feats.iter().all(|f| f.is_finite()));
        // c0 of silence = sqrt(n)·ln(floor) — strongly negative.
        assert!(feats[0] < -100.0);
    }

    #[test]
    fn time_shift_by_hop_shifts_frames() {
        let m = Mfcc::new(16_000, 400, 160, 40);
        let mut rng = Rng::new(5);
        let sig: Vec<f32> = (0..2000).map(|_| rng.uniform(-0.5, 0.5)).collect();
        let a = m.extract(&sig);
        let b = m.extract(&sig[160..]);
        // Frame k of shifted signal == frame k+1 of original.
        let n = m.n_mels;
        for k in 0..m.frames_in(sig.len() - 160) {
            for d in 0..n {
                assert!((a[(k + 1) * n + d] - b[k * n + d]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn deterministic() {
        let m = Mfcc::new(16_000, 400, 160, 80);
        let sig = tone(700.0, 800, 16_000.0);
        assert_eq!(m.extract(&sig), m.extract(&sig));
    }
}
