//! Signal-processing front-end: framing, pre-emphasis, windowing, FFT,
//! mel filterbank, DCT — the MFCC pipeline of §2.1 (Fig. 3).

pub mod fft;
pub mod mel;
pub mod mfcc;

pub use fft::FftPlan;
pub use mel::{Dct, MelBank};
pub use mfcc::Mfcc;
