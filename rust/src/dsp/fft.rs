//! Radix-2 Cooley–Tukey FFT, implemented from scratch (the feature
//! extraction kernel of §2.1 / §4.2 — on ASRPU this is kernel 0 of the
//! acoustic scoring phase, here it is the native front-end and the
//! instruction-count reference for the simulator's MFCC kernel model).

use std::f64::consts::PI;

/// Precomputed plan for a power-of-two complex FFT.
#[derive(Debug, Clone)]
pub struct FftPlan {
    n: usize,
    /// Bit-reversal permutation.
    rev: Vec<u32>,
    /// Twiddle factors for each butterfly stage, concatenated.
    twiddles_re: Vec<f32>,
    twiddles_im: Vec<f32>,
}

impl FftPlan {
    /// Build a plan for size `n` (must be a power of two ≥ 2).
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n >= 2, "FFT size must be a power of two, got {n}");
        let bits = n.trailing_zeros();
        let rev: Vec<u32> = (0..n as u32)
            .map(|i| i.reverse_bits() >> (32 - bits))
            .collect();
        // Stage s has half-width m = 2^s; twiddle w^k = exp(-2πik/(2m)).
        let mut twiddles_re = Vec::with_capacity(n - 1);
        let mut twiddles_im = Vec::with_capacity(n - 1);
        let mut m = 1;
        while m < n {
            for k in 0..m {
                let ang = -PI * (k as f64) / (m as f64);
                twiddles_re.push(ang.cos() as f32);
                twiddles_im.push(ang.sin() as f32);
            }
            m *= 2;
        }
        FftPlan { n, rev, twiddles_re, twiddles_im }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    /// In-place forward FFT over split re/im buffers of length `n`.
    pub fn forward(&self, re: &mut [f32], im: &mut [f32]) {
        let n = self.n;
        assert_eq!(re.len(), n);
        assert_eq!(im.len(), n);
        // Bit-reversal permutation.
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        // Butterflies.
        let mut m = 1;
        let mut tw_base = 0;
        while m < n {
            for start in (0..n).step_by(2 * m) {
                for k in 0..m {
                    let wr = self.twiddles_re[tw_base + k];
                    let wi = self.twiddles_im[tw_base + k];
                    let i = start + k;
                    let j = i + m;
                    let tr = wr * re[j] - wi * im[j];
                    let ti = wr * im[j] + wi * re[j];
                    re[j] = re[i] - tr;
                    im[j] = im[i] - ti;
                    re[i] += tr;
                    im[i] += ti;
                }
            }
            tw_base += m;
            m *= 2;
        }
    }

    /// Real-input FFT: returns the one-sided power spectrum
    /// `|X[k]|²` for `k = 0..=n/2` (length n/2 + 1). Input shorter than
    /// `n` is zero-padded.
    pub fn power_spectrum(&self, input: &[f32], out: &mut Vec<f32>) {
        let mut re = Vec::new();
        let mut im = Vec::new();
        self.power_spectrum_scratch(input, &mut re, &mut im, out);
    }

    /// Allocation-free variant: `re`/`im` are reused scratch buffers
    /// (§Perf: the MFCC hot loop calls this once per frame).
    pub fn power_spectrum_scratch(
        &self,
        input: &[f32],
        re: &mut Vec<f32>,
        im: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) {
        let n = self.n;
        assert!(input.len() <= n, "input longer than FFT size");
        re.clear();
        re.extend_from_slice(input);
        re.resize(n, 0.0);
        im.clear();
        im.resize(n, 0.0);
        self.forward(re, im);
        out.clear();
        out.extend((0..=n / 2).map(|k| re[k] * re[k] + im[k] * im[k]));
    }
}

/// Naive O(n²) DFT power spectrum — correctness oracle for tests.
#[cfg(test)]
pub fn naive_power_spectrum(input: &[f32], n: usize) -> Vec<f32> {
    (0..=n / 2)
        .map(|k| {
            let (mut re, mut im) = (0.0f64, 0.0f64);
            for (t, &x) in input.iter().enumerate() {
                let ang = -2.0 * PI * (k * t) as f64 / n as f64;
                re += x as f64 * ang.cos();
                im += x as f64 * ang.sin();
            }
            (re * re + im * im) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn impulse_has_flat_spectrum() {
        let plan = FftPlan::new(64);
        let mut input = vec![0.0; 64];
        input[0] = 1.0;
        let mut ps = Vec::new();
        plan.power_spectrum(&input, &mut ps);
        for &p in &ps {
            assert!((p - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn pure_tone_peaks_at_bin() {
        let n = 512;
        let plan = FftPlan::new(n);
        let bin = 37;
        let input: Vec<f32> = (0..n)
            .map(|t| (2.0 * PI * bin as f64 * t as f64 / n as f64).cos() as f32)
            .collect();
        let mut ps = Vec::new();
        plan.power_spectrum(&input, &mut ps);
        let peak = ps
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(peak, bin);
        // Energy of cos at exact bin: (n/2)^2.
        let expect = (n as f32 / 2.0).powi(2);
        assert!((ps[bin] / expect - 1.0).abs() < 1e-3);
    }

    #[test]
    fn matches_naive_dft_random_inputs() {
        prop::check("fft-matches-naive-dft", 30, |g| {
            let n = 1 << (3 + g.index(5)); // 8..128
            let len = g.len(1).min(n);
            let input = g.vec_of(len, |r| r.uniform(-1.0, 1.0));
            let plan = FftPlan::new(n);
            let mut fast = Vec::new();
            plan.power_spectrum(&input, &mut fast);
            let slow = naive_power_spectrum(&input, n);
            for (k, (a, b)) in fast.iter().zip(&slow).enumerate() {
                let scale = 1.0 + b.abs();
                crate::prop_assert!(
                    (a - b).abs() / scale < 1e-3,
                    "n={n} bin {k}: fft={a} dft={b}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn parseval_energy_conservation() {
        let n = 256;
        let plan = FftPlan::new(n);
        let mut rng = Rng::new(99);
        let input: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut re = input.clone();
        let mut im = vec![0.0; n];
        plan.forward(&mut re, &mut im);
        let time_energy: f64 = input.iter().map(|&x| (x as f64).powi(2)).sum();
        let freq_energy: f64 = re
            .iter()
            .zip(&im)
            .map(|(&r, &i)| (r as f64).powi(2) + (i as f64).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!((time_energy - freq_energy).abs() / time_energy < 1e-5);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two() {
        FftPlan::new(100);
    }
}
