//! `asrpu` — CLI for the ASRPU reproduction.
//!
//! Subcommands:
//!   decode    decode synthetic utterances end-to-end (XLA artifacts or
//!             native backend), report transcripts + WER + RTF;
//!             `--nbest N` records the exact lattice and prints the
//!             N-best list for the first utterance, `--rescore W` adds
//!             a trigram second pass at weight W (implies --nbest 8)
//!   serve     JSON-lines TCP streaming server, protocol v2
//!             (hello/open/feed/finish/resume/nbest/stats/config with
//!             structured error codes; v1 lines still accepted — see
//!             coordinator::server); `--workers N` shards sessions
//!             across N device workers over the shared model,
//!             `--rebalance K` sets the live-migration imbalance
//!             threshold, `--checkpoint K` the recovery-checkpoint
//!             cadence in decoding steps (0 = off); overload policy:
//!             `--admit N` caps open sessions per shard (reject with
//!             backpressure + retry hint), `--retry-after MS` sets the
//!             hint, `--shed 1` sheds the oldest never-started session
//!             off a saturated shard, `--route-retries N` /
//!             `--route-backoff MS` retry full shard queues before
//!             bouncing, `--degrade B` installs the two-rung reference
//!             degradation ladder entered at backlog B decode steps;
//!             `--nbest N` / `--rescore W` enable the lattice N-best
//!             subsystem behind the protocol's `nbest` op;
//!             `--max-workers N` caps the elastic pool (the `pool` op's
//!             `add` action scales up to it at runtime, `drain`
//!             migrates a shard empty and retires it), `--drain MS`
//!             bounds how long a drain migrates before reverting
//!   simulate  run the accelerator simulator for N decoding steps;
//!             `--batch B --shards S` additionally reports the fused
//!             step sharded across S worker devices
//!   report    regenerate paper tables/figures: table1 table2 fig9 fig10
//!             fig11 headline all
//!   sweep     design-space sweep over PEs / MAC width / frequency
//!   synth     render a synthetic utterance to raw f32 samples on stdout
//!
//! Engines are constructed through `Engine::builder()` exclusively:
//! `--backend native|xla|auto` picks the model source, `--beam` the
//! search width, `--batch`/`--batch-wait` the serving batch policy; the
//! builder validates the combination and reports typed errors.
//! Weight formats (native backend): `--precision f32|int8|int4|
//! int4_sparse` quantizes every layer uniformly; `--precision-map M`
//! applies the per-layer calibration output instead, either inline
//! (`int4,output.fc=int8`) or `@DIR` to load `DIR/precision.bin`
//! written by `python/compile/calibrate.py`.

use anyhow::{anyhow, bail, Result};

use asrpu::accel::{simulate_step, simulate_step_sharded, HypWorkload, SimMode};
use asrpu::am::TdsModel;
use asrpu::config::{
    artifacts_dir, AccelConfig, BatchConfig, DecoderConfig, ModelConfig, OverloadPolicy,
    Precision, PrecisionMap, ShardConfig,
};
use asrpu::coordinator::{Engine, EngineBuilder, Server};
use asrpu::decoder::TrigramLm;
use asrpu::power::ChipBudget;
use asrpu::report;
use asrpu::runtime::Runtime;
use asrpu::synth::{spec, Synthesizer, WerAccum};
use asrpu::util::cli;
use asrpu::util::rng::Rng;
use asrpu::util::table::Table;

const VALUE_KEYS: &[&str] = &[
    "n", "seed", "beam", "port", "pes", "mac", "freq-mhz", "backend", "mode", "steps",
    "queue", "batch", "batch-wait", "workers", "rebalance", "checkpoint", "shards",
    "admit", "retry-after", "shed", "route-retries", "route-backoff", "degrade",
    "nbest", "rescore", "max-workers", "drain", "precision", "precision-map",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = cli::parse(argv, VALUE_KEYS)?;
    match args.subcommand.as_deref() {
        Some("decode") => cmd_decode(&args),
        Some("serve") => cmd_serve(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("report") => cmd_report(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("synth") => cmd_synth(&args),
        _ => {
            eprintln!(
                "usage: asrpu <decode|serve|simulate|report|sweep|synth> [options]\n\
                 see rust/src/main.rs header for details"
            );
            Ok(())
        }
    }
}

/// A builder configured from the shared CLI flags (`--backend`,
/// `--beam`); subcommands add their own knobs before `.build()`.
fn engine_builder(args: &cli::Args) -> Result<EngineBuilder> {
    let beam = args.f64_or("beam", DecoderConfig::default().beam as f64)? as f32;
    let builder = Engine::builder().beam(beam);
    let builder = match args.str_or("backend", "auto").as_str() {
        "native" => builder.native(TdsModel::random(ModelConfig::tiny_tds(), 1)),
        "xla" => {
            let rt = Runtime::cpu()?;
            builder.artifacts(&rt, artifacts_dir())
        }
        "auto" => {
            if artifacts_dir().join("meta.json").exists() {
                let rt = Runtime::cpu()?;
                builder.artifacts(&rt, artifacts_dir())
            } else {
                eprintln!("note: artifacts missing; using native backend with random weights");
                builder.native(TdsModel::random(ModelConfig::tiny_tds(), 1))
            }
        }
        other => bail!("unknown backend '{other}' (native|xla|auto)"),
    };
    // Lattice N-best + optional second pass: `--nbest N` turns on exact
    // lattice recording, `--rescore W` adds a trigram rescorer at weight
    // W over the same synthetic corpus the first-pass bigram is
    // estimated from (and implies --nbest 8 when unset).
    let mut builder = builder.nbest(args.usize_or("nbest", 0)?);
    let rescore_w = args.f64_or("rescore", 0.0)?;
    if rescore_w != 0.0 {
        let tri = TrigramLm::estimate(&spec::sample_corpus(2000, 7777), 0.4)?;
        builder = builder.rescore(tri, rescore_w as f32);
    }
    // Weight formats: `--precision` quantizes every layer of a native
    // model uniformly; `--precision-map` applies the per-layer
    // calibration result, inline (`int4,output.fc=int8`) or `@DIR` for
    // DIR/precision.bin from the compile-side calibration pass.
    let precision = args.str_or("precision", "");
    if !precision.is_empty() {
        builder = builder.precision(Precision::parse(&precision).map_err(|e| anyhow!(e))?);
    }
    let pmap = args.str_or("precision-map", "");
    if !pmap.is_empty() {
        let map = match pmap.strip_prefix('@') {
            Some(dir) => {
                PrecisionMap::from_artifacts(&ModelConfig::tiny_tds(), std::path::Path::new(dir))
            }
            None => PrecisionMap::parse(&pmap),
        }
        .map_err(|e| anyhow!(e))?;
        builder = builder.precision_map(map);
    }
    Ok(builder)
}

fn build_engine(args: &cli::Args) -> Result<Engine> {
    Ok(engine_builder(args)?.build()?)
}

fn cmd_decode(args: &cli::Args) -> Result<()> {
    let n = args.usize_or("n", 8)?;
    let seed = args.usize_or("seed", 42)? as u64;
    let engine = build_engine(args)?;
    let synth = Synthesizer::default();
    let mut rng = Rng::new(seed);
    let mut wer = WerAccum::default();
    let mut table = Table::new(
        "decode — synthetic utterances",
        &["#", "reference", "hypothesis", "edits", "steps", "RTF"],
    );
    let mut total_compute = 0.0;
    let mut total_audio = 0.0;
    // With --nbest the first utterance's exact N-best list (and second
    // pass, with --rescore) prints after the table.
    let mut first_nbest = None;
    for i in 0..n {
        let words = spec::sample_sentence(&mut rng);
        let u = synth.render(&words, &mut rng);
        let (t, m) = if engine.nbest_n() > 0 {
            let mut s = engine.open(false)?;
            engine.feed(&mut s, &u.samples)?;
            let r = engine.nbest(&mut s)?;
            let m = s.metrics;
            if first_nbest.is_none() {
                first_nbest = Some((r.entries, r.rescored));
            }
            (r.transcript, m)
        } else {
            engine.decode_utterance(&u.samples)?
        };
        let edits = asrpu::synth::edit_distance(&u.words, &t.words);
        wer.add(&u.words, &t.words);
        total_compute += m.compute_s;
        total_audio += m.audio_s;
        table.row(&[
            i.to_string(),
            u.text.clone(),
            t.text.clone(),
            edits.to_string(),
            m.steps.to_string(),
            format!("{:.1}x", m.rtf()),
        ]);
    }
    table.footnote = Some(format!(
        "WER {:.2}% ({} edits / {} words), sentence acc {:.0}%, aggregate RTF {:.1}x",
        wer.wer() * 100.0,
        wer.edits,
        wer.ref_words,
        wer.sentence_acc() * 100.0,
        total_audio / total_compute
    ));
    println!("{}", table.render());
    if let Some((entries, rescored)) = first_nbest {
        println!("N-best for utterance 0 (first-pass / second-pass scores):");
        for (i, e) in entries.iter().enumerate() {
            // The rescored list is re-ranked by second-pass score;
            // match this entry by word sequence.
            let second = rescored
                .as_ref()
                .and_then(|v| v.iter().find(|x| x.words == e.words))
                .map(|x| x.second_pass)
                .unwrap_or(e.score);
            println!("  {:>2}.  {:>10.3}  {:>10.3}  {}", i + 1, e.score, second, e.text);
        }
    }
    Ok(())
}

/// The argv `serve` rebuilds its engine from on the device thread (PJRT
/// handles are not `Send`, so the engine cannot cross threads — its
/// *recipe* does). Every engine-shaping flag must be threaded through
/// here: dropping one silently respawns a default-configured engine.
/// `--beam` was exactly such a drop (KNOWN_FAILURES, fixed in PR 9).
fn respawn_argv(
    backend: &str,
    beam: f64,
    nbest: usize,
    rescore: f64,
    precision: &str,
    precision_map: &str,
) -> Vec<String> {
    let mut argv = vec![
        "serve".to_string(),
        "--backend".into(),
        backend.to_string(),
        "--beam".into(),
        beam.to_string(),
        "--nbest".into(),
        nbest.to_string(),
        "--rescore".into(),
        rescore.to_string(),
    ];
    if !precision.is_empty() {
        argv.push("--precision".into());
        argv.push(precision.to_string());
    }
    if !precision_map.is_empty() {
        argv.push("--precision-map".into());
        argv.push(precision_map.to_string());
    }
    argv
}

fn cmd_serve(args: &cli::Args) -> Result<()> {
    let port = args.usize_or("port", 7700)?;
    let queue = args.usize_or("queue", 128)?;
    let backend = args.str_or("backend", "auto");
    let beam = args.f64_or("beam", DecoderConfig::default().beam as f64)?;
    let nbest = args.usize_or("nbest", 0)?;
    let rescore = args.f64_or("rescore", 0.0)?;
    let precision = args.str_or("precision", "");
    let precision_map = args.str_or("precision-map", "");
    let batch_default = BatchConfig::default();
    let batch = BatchConfig {
        max_batch: args.usize_or("batch", batch_default.max_batch)?,
        max_wait_frames: args.usize_or("batch-wait", batch_default.max_wait_frames)?,
    };
    let shard_default = ShardConfig::default();
    let shards = ShardConfig {
        workers: args.usize_or("workers", shard_default.workers)?,
        rebalance_threshold: args
            .usize_or("rebalance", shard_default.rebalance_threshold)?,
        checkpoint_interval: args
            .usize_or("checkpoint", shard_default.checkpoint_interval)?,
        max_workers: args.usize_or("max-workers", shard_default.max_workers)?,
        drain_deadline_ms: args
            .usize_or("drain", shard_default.drain_deadline_ms as usize)?
            as u64,
    };
    let overload_default = OverloadPolicy::default();
    let degrade_base = args.usize_or("degrade", 0)?;
    let overload = OverloadPolicy {
        admit_sessions_per_shard: args.usize_or("admit", 0)?,
        retry_after_ms: args.usize_or("retry-after", overload_default.retry_after_ms as usize)?
            as u64,
        shed_never_started: args.usize_or("shed", 0)? != 0,
        shed_memory: overload_default.shed_memory,
        route_retries: args.usize_or("route-retries", 0)? as u32,
        route_backoff_ms: args
            .usize_or("route-backoff", overload_default.route_backoff_ms as usize)?
            as u64,
        // `--degrade B` installs the reference two-rung ladder scaled to
        // the configured beam and batch geometry; 0 = full quality only.
        levels: if degrade_base == 0 {
            Vec::new()
        } else {
            let dec = DecoderConfig {
                beam: args.f64_or("beam", DecoderConfig::default().beam as f64)? as f32,
                ..DecoderConfig::default()
            };
            OverloadPolicy::reference_ladder(degrade_base, &dec, &batch).levels
        },
    };
    // Fail fast on the CLI thread; the builder re-validates on the
    // device thread.
    batch.validate()?;
    shards.validate()?;
    overload.validate()?;
    let server = Server::start(
        &format!("127.0.0.1:{port}"),
        move || {
            // Rebuild the engine on the device thread (PJRT not Send).
            let argv = respawn_argv(&backend, beam, nbest, rescore, &precision, &precision_map);
            let args = cli::parse(&argv, VALUE_KEYS)?;
            Ok(engine_builder(&args)?
                .batch(batch)
                .shards(shards)
                .overload(overload.clone())
                .build()?)
        },
        queue,
    )?;
    println!(
        "asrpu serving on {} (JSON lines, protocol v2; ops: \
         hello/open/feed/finish/resume/nbest/stats/config/pool; \
         {} lane-batched device worker(s))",
        server.addr,
        server.workers()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_simulate(args: &cli::Args) -> Result<()> {
    let steps = args.usize_or("steps", 10)?;
    let mut accel = AccelConfig::paper();
    accel.num_pes = args.usize_or("pes", accel.num_pes)?;
    accel.mac_vector_width = args.usize_or("mac", accel.mac_vector_width)?;
    accel.frequency_hz = args.usize_or("freq-mhz", 500)? as u64 * 1_000_000;
    accel.validate()?;
    let model = ModelConfig::paper_tds();
    let mode = match args.str_or("mode", "ideal").as_str() {
        "ideal" => SimMode::Ideal,
        "detailed" => SimMode::Detailed,
        other => bail!("unknown mode '{other}' (ideal|detailed)"),
    };
    let r = simulate_step(&model, &accel, &HypWorkload::default(), mode);
    let ms = r.seconds(&accel) * 1e3;
    println!(
        "decoding step: {:.2} ms ({} cycles, {} instrs, util {:.1}%)",
        ms,
        r.total_cycles,
        r.total_instrs,
        100.0 * r.utilization(&accel)
    );
    println!(
        "rtf {:.2}x  acoustic {:.2} ms  hyp-expansion {:.2} ms  dma stalls {} cycles",
        r.rtf(&model, &accel),
        r.acoustic_cycles as f64 * accel.cycle_s() * 1e3,
        r.hyp_cycles as f64 * accel.cycle_s() * 1e3,
        r.dma_stall_cycles
    );
    println!(
        "utterance of {} steps: {:.1} ms audio decoded in {:.1} ms",
        steps,
        steps as f64 * model.step_seconds() * 1e3,
        steps as f64 * ms
    );
    // Multi-stream serving mapped onto worker devices: report the fused
    // step sharded across S workers (the coordinator's ShardPool shape).
    let batch = args.usize_or("batch", 1)?;
    let shards = args.usize_or("shards", 1)?;
    anyhow::ensure!(batch >= 1, "need at least one lane (--batch)");
    anyhow::ensure!(shards >= 1, "need at least one shard (--shards)");
    if batch > 1 || shards > 1 {
        let s = simulate_step_sharded(&model, &accel, &HypWorkload::default(), mode, batch, shards);
        println!(
            "sharded: {} lanes over {} worker(s) (lanes {:?}): step {:.2} ms, \
             aggregate rtf {:.2}x, weight DMA {:.1} MB/step",
            s.total_lanes(),
            s.per_shard.len(),
            s.lanes,
            s.seconds(&accel) * 1e3,
            s.rtf_aggregate(&model, &accel),
            s.total_dma_bytes() as f64 / 1e6
        );
    }
    Ok(())
}

fn cmd_report(args: &cli::Args) -> Result<()> {
    let accel = AccelConfig::paper();
    let model = ModelConfig::paper_tds();
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let out = match which {
        "table1" => report::table1().render(),
        "table2" => report::table2(&accel).render(),
        "fig9" => {
            let (t, c) = report::fig9(&model);
            format!("{}{}", t.render(), c)
        }
        "fig10" => {
            let (t, c) = report::fig10(&accel);
            format!("{}{}", t.render(), c)
        }
        "fig11" => {
            let (t, c, _) = report::fig11(&model, &accel, SimMode::Ideal);
            format!("{}{}", t.render(), c)
        }
        "headline" => report::headline(&model, &accel).render(),
        "all" => report::all_reports(),
        other => bail!("unknown report '{other}'"),
    };
    println!("{out}");
    Ok(())
}

fn cmd_sweep(args: &cli::Args) -> Result<()> {
    let pes = args.range_or("pes", (1, 16, 1))?;
    let model = ModelConfig::paper_tds();
    let mut t = Table::new(
        "design-space sweep — PEs vs step time / RTF / area / peak power",
        &["PEs", "Step (ms)", "RTF", "Area (mm2)", "Peak (W)", "mJ/step"],
    );
    for p in pes {
        let mut accel = AccelConfig::paper();
        accel.num_pes = p;
        accel.validate()?;
        let r = simulate_step(&model, &accel, &HypWorkload::default(), SimMode::Ideal);
        let b = ChipBudget::for_config(&accel);
        let e = asrpu::power::step_energy_j(&r, &accel);
        t.row(&[
            p.to_string(),
            format!("{:.1}", r.seconds(&accel) * 1e3),
            format!("{:.2}", r.rtf(&model, &accel)),
            format!("{:.2}", b.total_area_mm2()),
            format!("{:.2}", b.total_peak_w()),
            format!("{:.1}", e * 1e3),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_synth(args: &cli::Args) -> Result<()> {
    let seed = args.usize_or("seed", 1)? as u64;
    let mut rng = Rng::new(seed);
    let synth = Synthesizer::default();
    let u = synth.render_random(&mut rng);
    eprintln!("text: {}", u.text);
    eprintln!(
        "samples: {} ({:.2}s)",
        u.samples.len(),
        u.samples.len() as f64 / 16000.0
    );
    // Raw little-endian f32 samples on stdout (pipe to a file / player).
    use std::io::Write;
    let mut out = std::io::stdout().lock();
    for s in &u.samples {
        out.write_all(&s.to_le_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_subcommands_run() {
        for which in ["table1", "table2", "fig9", "fig10", "fig11", "headline"] {
            run(&["report".to_string(), which.to_string()]).unwrap();
        }
    }

    #[test]
    fn simulate_runs() {
        run(&["simulate".to_string()]).unwrap();
    }

    #[test]
    fn simulate_sharded_runs() {
        run(&[
            "simulate".to_string(),
            "--batch".into(),
            "8".into(),
            "--shards".into(),
            "2".into(),
        ])
        .unwrap();
    }

    #[test]
    fn respawn_argv_preserves_custom_beam() {
        // Regression (KNOWN_FAILURES, PR 8): the device-thread respawn
        // argv dropped `--beam`, so `serve --beam 6` rebuilt an engine
        // at the default width. The rebuilt engine must carry the
        // custom beam exactly.
        let custom = 6.5f64;
        assert_ne!(custom as f32, DecoderConfig::default().beam);
        let argv = respawn_argv("native", custom, 0, 0.0, "", "");
        let args = cli::parse(&argv, VALUE_KEYS).unwrap();
        let engine = engine_builder(&args).unwrap().build().unwrap();
        assert_eq!(engine.dec_cfg.beam, custom as f32);
    }

    #[test]
    fn precision_flag_quantizes_the_native_backend() {
        let args = cli::parse(
            &[
                "decode".to_string(),
                "--backend".into(),
                "native".into(),
                "--precision".into(),
                "int4".into(),
            ],
            VALUE_KEYS,
        )
        .unwrap();
        let engine = build_engine(&args).unwrap();
        assert_eq!(engine.backend().name(), "native-int4");
    }

    #[test]
    fn respawn_argv_preserves_precision_flags() {
        // The device-thread respawn must carry every engine-shaping flag
        // (the `--beam` drop class of bug); a serve with a calibration
        // map must rebuild the same mixed-precision backend.
        let argv = respawn_argv("native", 8.0, 0, 0.0, "", "int4,output.fc=int8");
        let args = cli::parse(&argv, VALUE_KEYS).unwrap();
        let engine = engine_builder(&args).unwrap().build().unwrap();
        assert_eq!(engine.backend().name(), "native-mixed");
        assert_eq!(
            engine.backend().precision_map(),
            PrecisionMap::parse("int4,output.fc=int8").unwrap()
        );
    }

    #[test]
    fn bad_precision_flag_errors() {
        let args = cli::parse(
            &["decode".to_string(), "--precision".into(), "int2".into()],
            VALUE_KEYS,
        )
        .unwrap();
        assert!(build_engine(&args).is_err());
    }

    #[test]
    fn unknown_backend_errors() {
        let args = cli::parse(
            &["decode".to_string(), "--backend".into(), "bogus".into()],
            VALUE_KEYS,
        )
        .unwrap();
        assert!(build_engine(&args).is_err());
    }
}
