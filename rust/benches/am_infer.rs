//! Bench: acoustic-model decoding step — native TDS vs XLA artifact
//! (the engine's hot path; §Perf L2/L3 target).
use asrpu::am::TdsModel;
use asrpu::bench::Bench;
use asrpu::config::{artifacts_dir, ModelConfig};
use asrpu::runtime::{Runtime, XlaAm};
use asrpu::util::rng::Rng;

fn main() {
    let mut b = Bench::default();
    let mut rng = Rng::new(2);
    let cfg = ModelConfig::tiny_tds();
    let feats: Vec<f32> =
        (0..cfg.frames_per_step() * cfg.n_mels).map(|_| rng.uniform(-1.0, 1.0)).collect();

    let native = TdsModel::random(cfg.clone(), 3);
    let mut st = native.state();
    b.run("am/native/tiny/step", || native.step(&mut st, &feats));

    if artifacts_dir().join("meta.json").exists() {
        let rt = Runtime::cpu().unwrap();
        let am = XlaAm::load(&rt, &artifacts_dir()).unwrap();
        let mut xst = am.state().unwrap();
        b.run("am/xla/tiny/step", || am.step(&mut xst, &feats).unwrap());
        let samples: Vec<f32> =
            (0..cfg.samples_per_step()).map(|_| rng.uniform(-0.5, 0.5)).collect();
        b.run("am/xla/tiny/mfcc", || am.mfcc(&samples).unwrap());
    } else {
        eprintln!("(artifacts missing; xla benches skipped)");
    }
}
