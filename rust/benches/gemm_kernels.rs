//! Bench: the AM micro-kernels head-to-head — naive (reference) vs tiled
//! (register-blocked 4×4) vs int8 `fc_batch` at a paper-scale FC shape
//! (1200×1200, the widest hidden FC of §5.2), swept over
//! B ∈ {1, 4, 16, 64} lanes.
//!
//! Reports GMAC/s per kernel per lane count and the tiled/int8 speedups
//! over naive, and writes the whole table to `BENCH_gemm.json` at the
//! repository root (consumed by CHANGES.md / perf tracking).

use asrpu::am::gemm;
use asrpu::am::quant::quantize_rows;
use asrpu::bench::Bench;
use asrpu::util::json::{Json, JsonObj};
use asrpu::util::rng::Rng;

const IN_DIM: usize = 1200;
const OUT_DIM: usize = 1200;

fn gmacs(batch: usize, secs: f64) -> f64 {
    (batch * IN_DIM * OUT_DIM) as f64 / secs / 1e9
}

fn main() {
    let mut rng = Rng::new(17);
    let w: Vec<f32> = (0..IN_DIM * OUT_DIM).map(|_| rng.uniform(-0.05, 0.05)).collect();
    let bias: Vec<f32> = (0..OUT_DIM).map(|_| rng.uniform(-0.1, 0.1)).collect();
    let qw = quantize_rows(&w, OUT_DIM, IN_DIM);

    let mut b = Bench::quick();
    let mut rows = Vec::new();
    for batch in [1usize, 4, 16, 64] {
        let xs: Vec<f32> = (0..batch * IN_DIM).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut out = vec![0.0f32; batch * OUT_DIM];
        let mut xsum = Vec::new();

        let naive = b
            .run(&format!("gemm/fc/naive/B{batch}"), || {
                gemm::fc_batch_naive_into(&w, &bias, &xs, batch, &mut out);
                out[0]
            })
            .median
            .as_secs_f64();
        let tiled = b
            .run(&format!("gemm/fc/tiled/B{batch}"), || {
                gemm::fc_batch_into(&w, &bias, &xs, batch, &mut out);
                out[0]
            })
            .median
            .as_secs_f64();
        let int8 = b
            .run(&format!("gemm/fc/int8/B{batch}"), || {
                gemm::fc_batch_int8_into(
                    &qw.q, &qw.scale, &qw.zp, &bias, &xs, batch, &mut xsum, &mut out,
                );
                out[0]
            })
            .median
            .as_secs_f64();
        rows.push((batch, naive, tiled, int8));
    }

    println!("\nGMAC/s by kernel and lane count (speedup vs naive):");
    let mut json_rows = Vec::new();
    for &(batch, naive, tiled, int8) in &rows {
        println!(
            "  B={batch:<3} naive {:>7.2}   tiled {:>7.2} ({:>5.2}x)   int8 {:>7.2} ({:>5.2}x)",
            gmacs(batch, naive),
            gmacs(batch, tiled),
            naive / tiled,
            gmacs(batch, int8),
            naive / int8,
        );
        let mut o = JsonObj::new();
        o.insert("batch", Json::Num(batch as f64));
        o.insert("naive_gmacs", Json::Num(gmacs(batch, naive)));
        o.insert("tiled_gmacs", Json::Num(gmacs(batch, tiled)));
        o.insert("int8_gmacs", Json::Num(gmacs(batch, int8)));
        o.insert("tiled_speedup", Json::Num(naive / tiled));
        o.insert("int8_speedup", Json::Num(naive / int8));
        json_rows.push(Json::Obj(o));
    }
    let mut doc = JsonObj::new();
    doc.insert("bench", Json::Str("gemm_kernels".into()));
    doc.insert("shape", Json::Str(format!("fc {OUT_DIM}x{IN_DIM}")));
    doc.insert("rows", Json::Arr(json_rows));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_gemm.json");
    match std::fs::write(&path, Json::Obj(doc).to_pretty()) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
