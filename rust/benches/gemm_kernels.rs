//! Bench: the AM micro-kernels head-to-head across kernel ISAs — the
//! naive FC reference (scalar-only baseline) plus the dispatched hot
//! kernels at every weight precision (`fc_batch`, `fc_batch_int8`,
//! `fc_batch_int4`, `fc_batch_int4_sparse`, `conv_steps`,
//! `conv_steps_int8`, `conv_steps_int4`, `conv_steps_int4_sparse`) at
//! paper-scale shapes, swept over B ∈ {1, 4, 16, 64} lanes and forced
//! to every ISA the host supports via `dispatch::with_forced_isa` (the
//! kernels are bit-identical across ISAs, so this is a pure throughput
//! A/B).
//!
//! Prints GMAC/s per kernel/ISA/lane count and the scalar→SIMD speedup
//! table, and writes schema-stable rows `{kernel, isa, batch, gmacs}`
//! to `BENCH_gemm.json` under `asrpu::bench::bench_dir()`
//! (`$ASRPU_BENCH_DIR`, default repo root), plus the int8-vs-below-int8
//! subset to `BENCH_quant.json`. CI uploads both files from every run —
//! the measured perf trajectory.

use asrpu::accel::kernels::peak_gmacs;
use asrpu::am::gemm;
use asrpu::am::gemm::dispatch::{self, KernelIsa};
use asrpu::am::quant::{prune_quantize_rows_2of4, quantize_rows, quantize_rows_int4};
use asrpu::bench::{bench_dir, Bench};
use asrpu::config::AccelConfig;
use asrpu::util::json::{Json, JsonObj};
use asrpu::util::rng::Rng;

/// FC shape: the widest hidden FC of §5.2 (1200×1200).
const IN_DIM: usize = 1200;
const OUT_DIM: usize = 1200;

/// Conv shape: a paper-like TDS group geometry — 10 channels over
/// 80-wide mel rows, kernel width 8, 4 output timesteps, stride 1.
const IN_CH: usize = 10;
const OUT_CH: usize = 10;
const KW: usize = 8;
const WIDTH: usize = 80;
const T_OUT: usize = 4;

const BATCHES: [usize; 4] = [1, 4, 16, 64];

fn fc_gmacs(batch: usize, secs: f64) -> f64 {
    (batch * IN_DIM * OUT_DIM) as f64 / secs / 1e9
}

fn conv_gmacs(batch: usize, secs: f64) -> f64 {
    (batch * T_OUT * OUT_CH * WIDTH * IN_CH * KW) as f64 / secs / 1e9
}

fn main() {
    let detected = dispatch::detect();
    let mut isas = vec![KernelIsa::Scalar];
    if detected != KernelIsa::Scalar {
        isas.push(detected);
    }
    println!(
        "detected kernel ISA: {detected}; device peak {:.0} GMAC/s (paper Table 2)",
        peak_gmacs(&AccelConfig::paper())
    );

    let mut rng = Rng::new(17);
    let w: Vec<f32> = (0..IN_DIM * OUT_DIM).map(|_| rng.uniform(-0.05, 0.05)).collect();
    let bias: Vec<f32> = (0..OUT_DIM).map(|_| rng.uniform(-0.1, 0.1)).collect();
    let qw = quantize_rows(&w, OUT_DIM, IN_DIM);
    let qw4 = quantize_rows_int4(&w, OUT_DIM, IN_DIM);
    let qws = prune_quantize_rows_2of4(&w, OUT_DIM, IN_DIM);
    let cw: Vec<f32> = (0..OUT_CH * IN_CH * KW).map(|_| rng.uniform(-0.2, 0.2)).collect();
    let cbias: Vec<f32> = (0..OUT_CH).map(|_| rng.uniform(-0.1, 0.1)).collect();
    let cq = quantize_rows(&cw, OUT_CH, IN_CH * KW);
    let cq4 = quantize_rows_int4(&cw, OUT_CH, IN_CH * KW);
    let cqs = prune_quantize_rows_2of4(&cw, OUT_CH, IN_CH * KW);

    let mut b = Bench::quick();
    // (kernel, isa, batch, gmacs) — the JSON schema, row per measurement.
    let mut rows: Vec<(String, KernelIsa, usize, f64)> = Vec::new();
    for batch in BATCHES {
        let xs: Vec<f32> = (0..batch * IN_DIM).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut out = vec![0.0f32; batch * OUT_DIM];
        let mut xsum = Vec::new();
        let mut gsum = Vec::new();
        let ext_len = (KW - 1 + T_OUT) * batch * IN_CH * WIDTH;
        let ext: Vec<f32> = (0..ext_len).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut cout = vec![0.0f32; T_OUT * batch * OUT_CH * WIDTH];
        let mut wsum = Vec::new();
        let mut tmp = Vec::new();

        // The naive kernel has no SIMD variant — it is the oracle the
        // dispatched kernels are verified bit-exact against.
        let naive = b
            .run(&format!("gemm/fc_naive/scalar/B{batch}"), || {
                gemm::fc_batch_naive_into(&w, &bias, &xs, batch, &mut out);
                out[0]
            })
            .median
            .as_secs_f64();
        rows.push(("fc_naive".into(), KernelIsa::Scalar, batch, fc_gmacs(batch, naive)));

        for &isa in &isas {
            let fc = dispatch::with_forced_isa(isa, || {
                b.run(&format!("gemm/fc/{isa}/B{batch}"), || {
                    gemm::fc_batch_into(&w, &bias, &xs, batch, &mut out);
                    out[0]
                })
                .median
                .as_secs_f64()
            });
            rows.push(("fc".into(), isa, batch, fc_gmacs(batch, fc)));

            let int8 = dispatch::with_forced_isa(isa, || {
                b.run(&format!("gemm/fc_int8/{isa}/B{batch}"), || {
                    gemm::fc_batch_int8_into(
                        &qw.q, &qw.scale, &qw.zp, &bias, &xs, batch, &mut xsum, &mut out,
                    );
                    out[0]
                })
                .median
                .as_secs_f64()
            });
            rows.push(("fc_int8".into(), isa, batch, fc_gmacs(batch, int8)));

            let int4 = dispatch::with_forced_isa(isa, || {
                b.run(&format!("gemm/fc_int4/{isa}/B{batch}"), || {
                    gemm::fc_batch_int4_into(
                        &qw4.packed, &qw4.scale, &qw4.zp, &bias, &xs, batch, &mut gsum,
                        &mut out,
                    );
                    out[0]
                })
                .median
                .as_secs_f64()
            });
            rows.push(("fc_int4".into(), isa, batch, fc_gmacs(batch, int4)));

            let sparse = dispatch::with_forced_isa(isa, || {
                b.run(&format!("gemm/fc_int4_sparse/{isa}/B{batch}"), || {
                    gemm::fc_batch_int4_sparse_into(
                        &qws.vals, &qws.idxs, &qws.scale, &bias, &xs, batch, &mut out,
                    );
                    out[0]
                })
                .median
                .as_secs_f64()
            });
            rows.push(("fc_int4_sparse".into(), isa, batch, fc_gmacs(batch, sparse)));

            let conv = dispatch::with_forced_isa(isa, || {
                b.run(&format!("gemm/conv/{isa}/B{batch}"), || {
                    gemm::conv_steps_into(
                        &cw, &cbias, &ext, T_OUT, 1, batch, IN_CH, OUT_CH, KW, WIDTH,
                        &mut cout,
                    );
                    cout[0]
                })
                .median
                .as_secs_f64()
            });
            rows.push(("conv".into(), isa, batch, conv_gmacs(batch, conv)));

            let conv8 = dispatch::with_forced_isa(isa, || {
                b.run(&format!("gemm/conv_int8/{isa}/B{batch}"), || {
                    gemm::conv_steps_int8_into(
                        &cq.q, &cq.scale, &cq.zp, &cbias, &ext, T_OUT, 1, batch, IN_CH,
                        OUT_CH, KW, WIDTH, &mut wsum, &mut cout,
                    );
                    cout[0]
                })
                .median
                .as_secs_f64()
            });
            rows.push(("conv_int8".into(), isa, batch, conv_gmacs(batch, conv8)));

            let conv4 = dispatch::with_forced_isa(isa, || {
                b.run(&format!("gemm/conv_int4/{isa}/B{batch}"), || {
                    gemm::conv_steps_int4_into(
                        &cq4.packed, &cq4.scale, &cq4.zp, &cbias, &ext, T_OUT, 1, batch,
                        IN_CH, OUT_CH, KW, WIDTH, &mut tmp, &mut cout,
                    );
                    cout[0]
                })
                .median
                .as_secs_f64()
            });
            rows.push(("conv_int4".into(), isa, batch, conv_gmacs(batch, conv4)));

            let convs = dispatch::with_forced_isa(isa, || {
                b.run(&format!("gemm/conv_int4_sparse/{isa}/B{batch}"), || {
                    gemm::conv_steps_int4_sparse_into(
                        &cqs.vals, &cqs.idxs, &cqs.scale, &cbias, &ext, T_OUT, 1, batch,
                        IN_CH, OUT_CH, KW, WIDTH, &mut cout,
                    );
                    cout[0]
                })
                .median
                .as_secs_f64()
            });
            rows.push(("conv_int4_sparse".into(), isa, batch, conv_gmacs(batch, convs)));
        }
    }

    if isas.len() > 1 {
        println!("\nscalar → {detected} speedup by kernel and lane count:");
        let kernels = [
            "fc", "fc_int8", "fc_int4", "fc_int4_sparse", "conv", "conv_int8", "conv_int4",
            "conv_int4_sparse",
        ];
        for kernel in kernels {
            for batch in BATCHES {
                let find = |isa: KernelIsa| {
                    rows.iter()
                        .find(|r| r.0 == kernel && r.1 == isa && r.2 == batch)
                        .map(|r| r.3)
                };
                if let (Some(s), Some(v)) = (find(KernelIsa::Scalar), find(detected)) {
                    println!(
                        "  {kernel:<10} B={batch:<3} {s:>8.2} → {v:>8.2} GMAC/s  ({:>5.2}x)",
                        v / s
                    );
                }
            }
        }
    } else {
        println!("\nscalar only — no SIMD kernel ISA detected on this host");
    }

    let mut json_rows = Vec::new();
    for (kernel, isa, batch, g) in &rows {
        let mut o = JsonObj::new();
        o.insert("kernel", Json::Str(kernel.clone()));
        o.insert("isa", Json::Str(isa.as_str().to_string()));
        o.insert("batch", Json::Num(*batch as f64));
        o.insert("gmacs", Json::Num(*g));
        json_rows.push(Json::Obj(o));
    }
    let mut doc = JsonObj::new();
    doc.insert("bench", Json::Str("gemm_kernels".into()));
    doc.insert("detected_isa", Json::Str(detected.as_str().to_string()));
    doc.insert(
        "shapes",
        Json::Str(format!(
            "fc {OUT_DIM}x{IN_DIM}; conv {OUT_CH}x{IN_CH}x{KW} w{WIDTH} t{T_OUT}"
        )),
    );
    doc.insert("rows", Json::Arr(json_rows));
    let path = bench_dir().join("BENCH_gemm.json");
    match std::fs::write(&path, Json::Obj(doc).to_pretty()) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }

    // The quantized-weight comparison CI tracks separately: int8 vs the
    // below-int8 formats, same schema, restricted to the quantized
    // kernels so the precision trajectory is one small file.
    let quant = [
        "fc_int8", "fc_int4", "fc_int4_sparse", "conv_int8", "conv_int4",
        "conv_int4_sparse",
    ];
    println!("\nquantized-weight kernels at {detected} (GMAC/s by lane count):");
    for kernel in quant {
        let per_batch: Vec<String> = BATCHES
            .iter()
            .filter_map(|&batch| {
                rows.iter()
                    .find(|r| r.0 == kernel && r.1 == detected && r.2 == batch)
                    .map(|r| format!("B{batch} {:.2}", r.3))
            })
            .collect();
        println!("  {kernel:<16} {}", per_batch.join("  "));
    }
    let mut quant_rows = Vec::new();
    for (kernel, isa, batch, g) in &rows {
        if !quant.contains(&kernel.as_str()) {
            continue;
        }
        let mut o = JsonObj::new();
        o.insert("kernel", Json::Str(kernel.clone()));
        o.insert("isa", Json::Str(isa.as_str().to_string()));
        o.insert("batch", Json::Num(*batch as f64));
        o.insert("gmacs", Json::Num(*g));
        quant_rows.push(Json::Obj(o));
    }
    let mut qdoc = JsonObj::new();
    qdoc.insert("bench", Json::Str("gemm_kernels_quant".into()));
    qdoc.insert("detected_isa", Json::Str(detected.as_str().to_string()));
    qdoc.insert(
        "shapes",
        Json::Str(format!(
            "fc {OUT_DIM}x{IN_DIM}; conv {OUT_CH}x{IN_CH}x{KW} w{WIDTH} t{T_OUT}"
        )),
    );
    qdoc.insert("rows", Json::Arr(quant_rows));
    let qpath = bench_dir().join("BENCH_quant.json");
    match std::fs::write(&qpath, Json::Obj(qdoc).to_pretty()) {
        Ok(()) => println!("wrote {}", qpath.display()),
        Err(e) => eprintln!("could not write {}: {e}", qpath.display()),
    }
}
