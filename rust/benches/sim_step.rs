//! Bench: accelerator-simulator throughput — a full paper-scale decoding
//! step simulation (169 kernel executions) must be fast enough for
//! design-space sweeps (§Perf L3 target: ≥10k steps/s).
use asrpu::accel::{build_step_kernels, simulate_step, HypWorkload, SimMode};
use asrpu::bench::Bench;
use asrpu::config::{AccelConfig, ModelConfig, PipelineDesc};
use asrpu::power::ChipBudget;

fn main() {
    let mut b = Bench::default();
    let model = ModelConfig::paper_tds();
    let accel = AccelConfig::paper();
    let hyp = HypWorkload::default();
    let pipe = PipelineDesc::for_model(&model);
    b.run("sim/build_kernels/paper", || build_step_kernels(&pipe, &accel, &hyp, 1).len());
    let r = b.run("sim/step/ideal", || {
        simulate_step(&model, &accel, &hyp, SimMode::Ideal).total_cycles
    });
    let per_s = r.per_sec();
    b.run("sim/step/detailed", || {
        simulate_step(&model, &accel, &hyp, SimMode::Detailed).total_cycles
    });
    b.run("sim/chip_budget", || ChipBudget::for_config(&accel).total_area_mm2());
    println!("sim throughput: {per_s:.0} ideal steps/s");
}
