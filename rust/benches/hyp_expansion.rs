//! Bench: hypothesis expansion + prune — the decoder's per-frame work
//! (the paper's hypothesis-expansion kernel + hypothesis unit, §4.3).
use asrpu::bench::Bench;
use asrpu::config::DecoderConfig;
use asrpu::decoder::BeamDecoder;
use asrpu::lm::NgramLm;
use asrpu::synth::spec;
use asrpu::util::rng::Rng;

fn main() {
    let mut b = Bench::default();
    let lex = spec::lexicon();
    let lm = NgramLm::estimate(&spec::sample_corpus(2000, 7777), 0.4).unwrap();
    let tokens = lex.tokens.len();
    let mut rng = Rng::new(3);
    for (beam, max_hyps) in [(6.0f32, 96usize), (14.0, 384)] {
        let dec = BeamDecoder::new(
            &lex,
            &lm,
            DecoderConfig { beam, max_hyps, ..Default::default() },
        )
        .unwrap();
        // Grow a realistic live set by stepping noisy frames.
        let mut state = dec.start();
        let frames: Vec<Vec<f32>> = (0..32)
            .map(|_| {
                let mut row: Vec<f32> = (0..tokens).map(|_| rng.uniform(-8.0, 0.0)).collect();
                row[rng.below(tokens as u64) as usize] = -0.1;
                row
            })
            .collect();
        for f in &frames {
            dec.step(&mut state, f);
        }
        let live = state.hyps.len();
        b.run(&format!("decoder/frame/beam{beam}/live{live}"), || {
            let mut s = state.clone();
            dec.step(&mut s, &frames[0]);
            s.hyps.len()
        });
    }
}
