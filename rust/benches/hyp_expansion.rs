//! Bench: hypothesis expansion + prune — the decoder's per-frame work
//! (the paper's hypothesis-expansion kernel + hypothesis unit, §4.3).
//!
//! Two tiers. The micro tier steps a lone `BeamDecoder` frame at two
//! beam settings (print-only). The engine tier drives fused
//! `Engine::step_batch` steps over B ∈ {1, 4, 16} lanes, with lattice
//! recording off and on (`EngineBuilder::nbest`) — the lane-major
//! expansion path end to end, lattice overhead measured on identical
//! audio. It writes schema-stable rows
//! `{kernel, batch, arcs_per_step, gmacs}` to `BENCH_hyp.json` under
//! `asrpu::bench::bench_dir()` (`$ASRPU_BENCH_DIR`, default repo
//! root): `arcs_per_step` is the *measured* per-step candidate-arc
//! count from the decoder's `PruneStats` (the same counters that feed
//! the `accel::HypUnit` model), `gmacs` the acoustic-model MAC
//! throughput sustained while decoding.

use asrpu::am::TdsModel;
use asrpu::bench::{bench_dir, Bench};
use asrpu::config::{DecoderConfig, ModelConfig, PipelineDesc};
use asrpu::coordinator::{Engine, Session};
use asrpu::decoder::BeamDecoder;
use asrpu::lm::NgramLm;
use asrpu::synth::spec;
use asrpu::util::json::{Json, JsonObj};
use asrpu::util::rng::Rng;

const BATCHES: [usize; 3] = [1, 4, 16];
const SAMPLES_PER_STEP: usize = 1280;
const WINDOW: usize = 1520;

/// One measured engine-tier configuration.
struct Row {
    kernel: &'static str,
    batch: usize,
    arcs_per_step: f64,
    gmacs: f64,
}

/// Bench fused stepping on `engine`: prime every lane, then time
/// "push one frame per lane + step_batch". Returns the measured row.
fn bench_engine(b: &mut Bench, kernel: &'static str, engine: &Engine, batch: usize) -> Row {
    let mut rng = Rng::new(29 + batch as u64);
    let chunks: Vec<Vec<f32>> = (0..batch)
        .map(|_| (0..SAMPLES_PER_STEP).map(|_| rng.uniform(-0.3, 0.3)).collect())
        .collect();
    let mut sessions: Vec<Session> =
        (0..batch).map(|_| engine.open(false).unwrap()).collect();
    // Pre-fill part of the first (wider) feature window so each
    // benched push of one hop's worth of samples readies exactly one
    // frame per lane.
    for (s, c) in sessions.iter_mut().zip(&chunks) {
        engine.push_audio(s, &c[..WINDOW - SAMPLES_PER_STEP]);
    }
    let secs = b
        .run(&format!("engine/{kernel}/B{batch}"), || {
            for (s, c) in sessions.iter_mut().zip(&chunks) {
                engine.push_audio(s, c);
            }
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            engine.step_batch(&mut refs).unwrap();
            sessions.iter().map(|s| s.decode.hyps.len()).sum::<usize>()
        })
        .median
        .as_secs_f64();
    let (mut arcs, mut steps) = (0u64, 0u64);
    for s in &sessions {
        arcs += s.decode.stats.generated;
        steps += s.decode.frames as u64;
    }
    let macs = PipelineDesc::for_model(&ModelConfig::tiny_tds()).macs_per_step();
    Row {
        kernel,
        batch,
        arcs_per_step: arcs as f64 / steps.max(1) as f64,
        gmacs: macs as f64 * batch as f64 / secs / 1e9,
    }
}

fn main() {
    let mut b = Bench::default();
    let lex = spec::lexicon();
    let lm = NgramLm::estimate(&spec::sample_corpus(2000, 7777), 0.4).unwrap();
    let tokens = lex.tokens.len();
    let mut rng = Rng::new(3);
    for (beam, max_hyps) in [(6.0f32, 96usize), (14.0, 384)] {
        let dec = BeamDecoder::new(
            &lex,
            &lm,
            DecoderConfig { beam, max_hyps, ..Default::default() },
        )
        .unwrap();
        // Grow a realistic live set by stepping noisy frames.
        let mut state = dec.start();
        let frames: Vec<Vec<f32>> = (0..32)
            .map(|_| {
                let mut row: Vec<f32> = (0..tokens).map(|_| rng.uniform(-8.0, 0.0)).collect();
                row[rng.below(tokens as u64) as usize] = -0.1;
                row
            })
            .collect();
        for f in &frames {
            dec.step(&mut state, f);
        }
        let live = state.hyps.len();
        b.run(&format!("decoder/frame/beam{beam}/live{live}"), || {
            let mut s = state.clone();
            dec.step(&mut s, &frames[0]);
            s.hyps.len()
        });
    }

    // Engine tier: the lane-major batched expansion path, lattice
    // recording off vs on, identical model seed and audio.
    let plain = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
        .build()
        .unwrap();
    let latt = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
        .nbest(4)
        .build()
        .unwrap();
    let mut rows = Vec::new();
    for batch in BATCHES {
        rows.push(bench_engine(&mut b, "step_batch", &plain, batch));
        rows.push(bench_engine(&mut b, "step_batch_lattice", &latt, batch));
    }

    println!("\nmeasured expansion workload and sustained AM throughput:");
    for r in &rows {
        println!(
            "  {:<18} B={:<3} {:>8.1} arcs/step  {:>7.3} GMAC/s",
            r.kernel, r.batch, r.arcs_per_step, r.gmacs
        );
    }

    let mut json_rows = Vec::new();
    for r in &rows {
        let mut o = JsonObj::new();
        o.insert("kernel", Json::Str(r.kernel.to_string()));
        o.insert("batch", Json::Num(r.batch as f64));
        o.insert("arcs_per_step", Json::Num(r.arcs_per_step));
        o.insert("gmacs", Json::Num(r.gmacs));
        json_rows.push(Json::Obj(o));
    }
    let mut doc = JsonObj::new();
    doc.insert("bench", Json::Str("hyp_expansion".into()));
    doc.insert("model", Json::Str("tiny_tds".into()));
    doc.insert("rows", Json::Arr(json_rows));
    let path = bench_dir().join("BENCH_hyp.json");
    match std::fs::write(&path, Json::Obj(doc).to_pretty()) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
