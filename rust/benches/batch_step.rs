//! Bench: the lane-batched execution core — fused AM+decoder steps at
//! B ∈ {1, 4, 16, 64} lanes, reporting frames/sec per configuration.
//!
//! Two workloads:
//!  * `tiny` — the end-to-end serving model (AM + beam search), swept
//!    across the full lane range;
//!  * `paper-f32` — the paper-scale acoustic model in f32 (AM only: its
//!    9000-token output layer has no matching lexicon), where the weight
//!    matrices dwarf every cache level and batching's
//!    stream-weights-once behaviour pays the most. The acceptance target
//!    for this refactor is ≥2× frames/sec at B=16 vs B=1 here.

use asrpu::am::{TdsModel, TdsState};
use asrpu::bench::Bench;
use asrpu::config::{DecoderConfig, ModelConfig, Precision};
use asrpu::decoder::{BeamDecoder, DecodeState};
use asrpu::lm::NgramLm;
use asrpu::synth::spec;
use asrpu::util::rng::Rng;

/// frames/sec of one fused step at `batch` lanes.
fn fps(batch: usize, frames_per_step: usize, median_s: f64) -> f64 {
    batch as f64 * frames_per_step as f64 / median_s
}

fn main() {
    let mut rng = Rng::new(11);

    // --- tiny serving model: fused AM + decoder step.
    let mut b = Bench::default();
    let model = TdsModel::random(ModelConfig::tiny_tds(), 3);
    let lex = spec::lexicon();
    let lm = NgramLm::estimate(&spec::sample_corpus(2000, 7777), 0.4).unwrap();
    let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
    let cfg = model.cfg.clone();
    let f = cfg.frames_per_step() * cfg.n_mels;
    let tokens = cfg.tokens;
    let vps = cfg.vectors_per_step();
    let mut tiny_fps = Vec::new();
    for batch in [1usize, 4, 16, 64] {
        let feats: Vec<f32> = (0..batch * f).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut am_states: Vec<TdsState> = (0..batch).map(|_| model.state()).collect();
        let mut dec_states: Vec<DecodeState> = (0..batch).map(|_| dec.start()).collect();
        let mut block = vec![0.0f32; batch * tokens];
        let r = b.run(&format!("batch/tiny/am+dec/B{batch}"), || {
            // Bound backtrack-arena growth across iterations while keeping
            // a realistically-sized live hypothesis set.
            if dec_states[0].frames > 256 {
                for st in dec_states.iter_mut() {
                    *st = dec.start();
                }
            }
            let mut refs: Vec<&mut TdsState> = am_states.iter_mut().collect();
            let logits = model.step_batch(&mut refs, &feats);
            for fr in 0..vps {
                for lane in 0..batch {
                    let src = (lane * vps + fr) * tokens;
                    block[lane * tokens..(lane + 1) * tokens]
                        .copy_from_slice(&logits[src..src + tokens]);
                }
                let mut drefs: Vec<&mut DecodeState> = dec_states.iter_mut().collect();
                dec.step_batch(&mut drefs, &block);
            }
            logits.len()
        });
        tiny_fps.push((batch, fps(batch, cfg.frames_per_step(), r.median.as_secs_f64())));
    }

    // --- paper-scale AM in f32: the memory-bound headline.
    let mut bq = Bench::quick();
    let paper_cfg = ModelConfig { precision: Precision::F32, ..ModelConfig::paper_tds() };
    let fps_frames = paper_cfg.frames_per_step();
    let paper = TdsModel::random(paper_cfg, 5);
    let pf = paper.cfg.frames_per_step() * paper.cfg.n_mels;
    let mut paper_fps = Vec::new();
    for batch in [1usize, 4, 16] {
        let feats: Vec<f32> = (0..batch * pf).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut states: Vec<TdsState> = (0..batch).map(|_| paper.state()).collect();
        let r = bq.run(&format!("batch/paper-f32/am/B{batch}"), || {
            let mut refs: Vec<&mut TdsState> = states.iter_mut().collect();
            paper.step_batch(&mut refs, &feats).len()
        });
        paper_fps.push((batch, fps(batch, fps_frames, r.median.as_secs_f64())));
    }

    println!("\nframes/sec by lane count (speedup vs B=1):");
    for (tag, series) in [("tiny am+dec", &tiny_fps), ("paper-f32 am", &paper_fps)] {
        let base = series[0].1;
        for &(batch, v) in series {
            println!("  {tag:<14} B={batch:<3} {v:>12.0} f/s   {:>5.2}x", v / base);
        }
    }
}
