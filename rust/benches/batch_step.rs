//! Bench: the lane-batched execution core — fused AM+decoder steps at
//! B ∈ {1, 4, 16, 64} lanes, reporting frames/sec per configuration.
//!
//! Two workloads:
//!  * `tiny` — the end-to-end serving model (AM + beam search), swept
//!    across the full lane range at the auto-detected kernel ISA;
//!  * `paper-f32` — the paper-scale acoustic model in f32 (AM only: its
//!    9000-token output layer has no matching lexicon), where the weight
//!    matrices dwarf every cache level and batching's
//!    stream-weights-once behaviour pays the most, A/B'd across every
//!    kernel ISA the host supports (`dispatch::with_forced_isa`). The
//!    acceptance target for the batching refactor is ≥2× frames/sec at
//!    B=16 vs B=1 here. The same model is then re-run with uniformly
//!    int8, int4, and 2:4-sparse int4 weights at the detected ISA
//!    (`paper_int8_am` / `paper_int4_am` / `paper_int4_sparse_am` rows)
//!    — the engine-level view of the below-int8 weight formats.
//!
//! Writes schema-stable rows `{kernel, isa, batch, gmacs}` to
//! `BENCH_batch_step.json` under `asrpu::bench::bench_dir()`
//! (`$ASRPU_BENCH_DIR`, default repo root); GMAC/s is derived from
//! `PipelineDesc::macs_per_step`. The `tiny_am_dec` rows time the beam
//! search too, so their GMAC/s understates pure AM throughput — useful
//! as a trajectory, not as a kernel roofline.

use asrpu::am::gemm::dispatch::{self, KernelIsa};
use asrpu::am::{QuantizedTdsModel, TdsModel, TdsState};
use asrpu::bench::{bench_dir, Bench};
use asrpu::config::{DecoderConfig, ModelConfig, PipelineDesc, Precision, PrecisionMap};
use asrpu::decoder::{BeamDecoder, DecodeState};
use asrpu::lm::NgramLm;
use asrpu::synth::spec;
use asrpu::util::json::{Json, JsonObj};
use asrpu::util::rng::Rng;

/// frames/sec of one fused step at `batch` lanes.
fn fps(batch: usize, frames_per_step: usize, median_s: f64) -> f64 {
    batch as f64 * frames_per_step as f64 / median_s
}

/// AM GMAC/s of one fused step at `batch` lanes.
fn gmacs(batch: usize, macs_per_step: u64, median_s: f64) -> f64 {
    batch as f64 * macs_per_step as f64 / median_s / 1e9
}

fn main() {
    let mut rng = Rng::new(11);
    let detected = dispatch::detect();
    let mut isas = vec![KernelIsa::Scalar];
    if detected != KernelIsa::Scalar {
        isas.push(detected);
    }
    // (kernel, isa, batch, gmacs) — the JSON schema, row per measurement.
    let mut rows: Vec<(String, KernelIsa, usize, f64)> = Vec::new();

    // --- tiny serving model: fused AM + decoder step.
    let mut b = Bench::default();
    let model = TdsModel::random(ModelConfig::tiny_tds(), 3);
    let lex = spec::lexicon();
    let lm = NgramLm::estimate(&spec::sample_corpus(2000, 7777), 0.4).unwrap();
    let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
    let cfg = model.cfg.clone();
    let f = cfg.frames_per_step() * cfg.n_mels;
    let tokens = cfg.tokens;
    let vps = cfg.vectors_per_step();
    let tiny_macs = PipelineDesc::for_model(&cfg).macs_per_step();
    let mut tiny_fps = Vec::new();
    for batch in [1usize, 4, 16, 64] {
        let feats: Vec<f32> = (0..batch * f).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut am_states: Vec<TdsState> = (0..batch).map(|_| model.state()).collect();
        let mut dec_states: Vec<DecodeState> = (0..batch).map(|_| dec.start()).collect();
        let mut block = vec![0.0f32; batch * tokens];
        let r = b.run(&format!("batch/tiny/am+dec/B{batch}"), || {
            // Bound backtrack-arena growth across iterations while keeping
            // a realistically-sized live hypothesis set.
            if dec_states[0].frames > 256 {
                for st in dec_states.iter_mut() {
                    *st = dec.start();
                }
            }
            let mut refs: Vec<&mut TdsState> = am_states.iter_mut().collect();
            let logits = model.step_batch(&mut refs, &feats);
            for fr in 0..vps {
                for lane in 0..batch {
                    let src = (lane * vps + fr) * tokens;
                    block[lane * tokens..(lane + 1) * tokens]
                        .copy_from_slice(&logits[src..src + tokens]);
                }
                let mut drefs: Vec<&mut DecodeState> = dec_states.iter_mut().collect();
                dec.step_batch(&mut drefs, &block);
            }
            logits.len()
        });
        let secs = r.median.as_secs_f64();
        tiny_fps.push((batch, fps(batch, cfg.frames_per_step(), secs)));
        rows.push((
            "tiny_am_dec".into(),
            KernelIsa::active(),
            batch,
            gmacs(batch, tiny_macs, secs),
        ));
    }

    // --- paper-scale AM in f32: the memory-bound headline, A/B'd per ISA.
    let mut bq = Bench::quick();
    let paper_cfg = ModelConfig { precision: Precision::F32, ..ModelConfig::paper_tds() };
    let fps_frames = paper_cfg.frames_per_step();
    let paper_macs = PipelineDesc::for_model(&paper_cfg).macs_per_step();
    let paper = TdsModel::random(paper_cfg, 5);
    let pf = paper.cfg.frames_per_step() * paper.cfg.n_mels;
    let mut paper_fps = Vec::new();
    for batch in [1usize, 4, 16] {
        let feats: Vec<f32> = (0..batch * pf).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut states: Vec<TdsState> = (0..batch).map(|_| paper.state()).collect();
        for &isa in &isas {
            let secs = dispatch::with_forced_isa(isa, || {
                bq.run(&format!("batch/paper-f32/am/{isa}/B{batch}"), || {
                    let mut refs: Vec<&mut TdsState> = states.iter_mut().collect();
                    paper.step_batch(&mut refs, &feats).len()
                })
                .median
                .as_secs_f64()
            });
            rows.push(("paper_f32_am".into(), isa, batch, gmacs(batch, paper_macs, secs)));
            if isa == detected {
                paper_fps.push((batch, fps(batch, fps_frames, secs)));
            }
        }
    }

    // --- paper-scale AM with quantized weights: int8 vs the below-int8
    // formats at the detected ISA — the engine-level weight-format A/B
    // the compile-side calibration pass banks on.
    let mut quant_g: Vec<(&str, usize, f64)> = Vec::new();
    for (tag, prec) in [
        ("paper_int8_am", Precision::Int8),
        ("paper_int4_am", Precision::Int4),
        ("paper_int4_sparse_am", Precision::Int4Sparse),
    ] {
        let qm = QuantizedTdsModel::from_model_mixed(&paper, &PrecisionMap::uniform(prec))
            .expect("paper model quantizes at every precision");
        for batch in [1usize, 4, 16] {
            let feats: Vec<f32> = (0..batch * pf).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut states: Vec<TdsState> = (0..batch).map(|_| qm.state()).collect();
            let secs = bq
                .run(&format!("batch/{tag}/{detected}/B{batch}"), || {
                    let mut refs: Vec<&mut TdsState> = states.iter_mut().collect();
                    qm.step_batch(&mut refs, &feats).len()
                })
                .median
                .as_secs_f64();
            let g = gmacs(batch, paper_macs, secs);
            rows.push((tag.into(), detected, batch, g));
            quant_g.push((tag, batch, g));
        }
    }

    println!("\nframes/sec by lane count (speedup vs B=1):");
    for (tag, series) in [("tiny am+dec", &tiny_fps), ("paper-f32 am", &paper_fps)] {
        let base = series[0].1;
        for &(batch, v) in series {
            println!("  {tag:<14} B={batch:<3} {v:>12.0} f/s   {:>5.2}x", v / base);
        }
    }
    if isas.len() > 1 {
        println!("\npaper-f32 AM scalar → {detected} speedup by lane count:");
        for batch in [1usize, 4, 16] {
            let find = |isa: KernelIsa| {
                rows.iter()
                    .find(|r| r.0 == "paper_f32_am" && r.1 == isa && r.2 == batch)
                    .map(|r| r.3)
            };
            if let (Some(s), Some(v)) = (find(KernelIsa::Scalar), find(detected)) {
                println!(
                    "  B={batch:<3} {s:>8.2} → {v:>8.2} GMAC/s  ({:>5.2}x)",
                    v / s
                );
            }
        }
    }

    println!("\npaper AM weight-format A/B at {detected} (GMAC/s, vs f32):");
    for &(tag, batch, g) in &quant_g {
        let f32_g = rows
            .iter()
            .find(|r| r.0 == "paper_f32_am" && r.1 == detected && r.2 == batch)
            .map(|r| r.3)
            .unwrap_or(g);
        println!("  {tag:<22} B={batch:<3} {g:>8.2} GMAC/s  ({:>5.2}x)", g / f32_g);
    }

    let mut json_rows = Vec::new();
    for (kernel, isa, batch, g) in &rows {
        let mut o = JsonObj::new();
        o.insert("kernel", Json::Str(kernel.clone()));
        o.insert("isa", Json::Str(isa.as_str().to_string()));
        o.insert("batch", Json::Num(*batch as f64));
        o.insert("gmacs", Json::Num(*g));
        json_rows.push(Json::Obj(o));
    }
    let mut doc = JsonObj::new();
    doc.insert("bench", Json::Str("batch_step".into()));
    doc.insert("detected_isa", Json::Str(detected.as_str().to_string()));
    doc.insert("rows", Json::Arr(json_rows));
    let path = bench_dir().join("BENCH_batch_step.json");
    match std::fs::write(&path, Json::Obj(doc).to_pretty()) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
