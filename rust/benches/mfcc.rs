//! Bench: native MFCC front-end — one decoding step of feature
//! extraction (the accelerator's kernel 0).
use asrpu::bench::Bench;
use asrpu::config::ModelConfig;
use asrpu::dsp::Mfcc;
use asrpu::util::rng::Rng;

fn main() {
    let mut b = Bench::default();
    let mut rng = Rng::new(1);
    for cfg in [ModelConfig::tiny_tds(), ModelConfig::paper_tds()] {
        let mfcc = Mfcc::for_model(&cfg);
        let samples: Vec<f32> =
            (0..cfg.samples_per_step()).map(|_| rng.uniform(-0.5, 0.5)).collect();
        b.run(&format!("mfcc/step/{}mel", cfg.n_mels), || mfcc.extract(&samples));
    }
    // Per-frame cost (the simulator's per-thread unit).
    let mfcc = Mfcc::new(16_000, 400, 160, 80);
    let samples: Vec<f32> = (0..400).map(|_| rng.uniform(-0.5, 0.5)).collect();
    let mut out = Vec::new();
    b.run("mfcc/frame/80mel", || {
        mfcc.frame(&samples, 0, &mut out);
        out.len()
    });
}
