//! Bench: full engine decoding step (MFCC + AM + search) and a whole
//! utterance — the end-to-end hot path (§Perf L3 target: step ≪ 80 ms).
use asrpu::am::TdsModel;
use asrpu::bench::Bench;
use asrpu::config::{artifacts_dir, DecoderConfig, ModelConfig};
use asrpu::coordinator::Engine;
use asrpu::runtime::Runtime;
use asrpu::synth::Synthesizer;
use asrpu::util::rng::Rng;

fn main() {
    let mut b = Bench::default();
    let mut rng = Rng::new(4);
    let u = Synthesizer::default().render(&[1, 2, 3, 4], &mut rng);
    let chunk: Vec<f32> = u.samples[..1520].to_vec();

    let native = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
        .decoder(DecoderConfig::default())
        .build()
        .unwrap();
    b.run("engine/native/step", || {
        let mut s = native.open(false).unwrap();
        native.feed(&mut s, &chunk).unwrap()
    });
    b.run("engine/native/utterance", || {
        native.decode_utterance(&u.samples).unwrap().0.words.len()
    });

    if artifacts_dir().join("meta.json").exists() {
        let rt = Runtime::cpu().unwrap();
        let xla = Engine::builder()
            .artifacts(&rt, artifacts_dir())
            .decoder(DecoderConfig::default())
            .build()
            .unwrap();
        b.run("engine/xla/step", || {
            let mut s = xla.open(false).unwrap();
            xla.feed(&mut s, &chunk).unwrap()
        });
        b.run("engine/xla/utterance", || {
            xla.decode_utterance(&u.samples).unwrap().0.words.len()
        });
    } else {
        eprintln!("(artifacts missing; xla benches skipped)");
    }
}
