# ASRPU reproduction — build-time targets.
#
# `make artifacts` trains the tiny TDS model (python/compile) and exports
# the AOT artifacts the Rust runtime consumes:
#   artifacts/model_step.hlo.txt  streaming step HLO text
#   artifacts/mfcc.hlo.txt        MFCC front-end HLO text
#   artifacts/weights.bin         tensor container (util/tensor_io)
#   artifacts/meta.json           geometry, parameter order, metrics
# Without them the artifact integration tests
# (rust/tests/cross_layer.rs, rust/tests/e2e_artifacts.rs, the xla half
# of rust/tests/builder_api.rs) and the xla-backed examples/benches skip
# gracefully.

PYTHON ?= python3
ARTIFACTS := artifacts

.PHONY: artifacts test bench fmt lint clean-artifacts

artifacts: $(ARTIFACTS)/meta.json

$(ARTIFACTS)/meta.json: python/compile/*.py
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS)

# The repo's tier-1 gate.
test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo bench

fmt:
	cd rust && cargo fmt

lint:
	cd rust && cargo fmt --check && cargo clippy --all-targets -- -D warnings

clean-artifacts:
	rm -rf $(ARTIFACTS)
