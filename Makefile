# ASRPU reproduction — build-time targets.
#
# `make artifacts` trains the tiny TDS model (python/compile) and exports
# the AOT artifacts the Rust runtime consumes:
#   artifacts/model_step.hlo.txt  streaming step HLO text
#   artifacts/mfcc.hlo.txt        MFCC front-end HLO text
#   artifacts/weights.bin         tensor container (util/tensor_io)
#   artifacts/meta.json           geometry, parameter order, metrics
#   artifacts/precision.bin       per-layer weight-format codes from the
#                                 calibration pass (compile/calibrate.py;
#                                 `asrpu ... --precision-map @artifacts`)
# Without them the artifact integration tests
# (rust/tests/cross_layer.rs, rust/tests/e2e_artifacts.rs, the xla half
# of rust/tests/builder_api.rs) and the xla-backed examples/benches skip
# gracefully.

PYTHON ?= python3
ARTIFACTS := artifacts

.PHONY: artifacts test bench fmt lint clean-artifacts

artifacts: $(ARTIFACTS)/meta.json

# No-op cleanly (with a notice) when JAX is absent: every consumer of
# the artifacts — the xla-gated tests, examples and benches — already
# skips gracefully when artifacts/meta.json does not exist, so a
# JAX-less machine should not turn `make artifacts` into a hard error.
$(ARTIFACTS)/meta.json: python/compile/*.py
	@if $(PYTHON) -c "import jax" 2>/dev/null; then \
		cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS) && \
		$(PYTHON) -m compile.calibrate --artifacts ../$(ARTIFACTS); \
	else \
		echo "make artifacts: JAX not importable by '$(PYTHON)'; skipping artifact export" ; \
		echo "               (xla-gated tests/examples will skip gracefully without it)"; \
	fi

# The repo's tier-1 gate.
test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo bench

fmt:
	cd rust && cargo fmt

lint:
	cd rust && cargo fmt --check && cargo clippy --all-targets -- -D warnings

clean-artifacts:
	rm -rf $(ARTIFACTS)
