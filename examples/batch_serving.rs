//! Batched multi-session serving: open N concurrent synthetic sessions,
//! stream their audio in 80 ms rounds through the engine's lane-batched
//! execution core, and print per-session transcripts plus aggregate RTF
//! and batch occupancy — the many-users-one-device scenario the
//! coordinator's `Batcher` exists for.
//!
//!     cargo run --release --example batch_serving [-- --n 16 --batch 8]

use std::time::Instant;

use asrpu::am::TdsModel;
use asrpu::config::{BatchConfig, DecoderConfig, ModelConfig};
use asrpu::coordinator::{Engine, Session};
use asrpu::synth::Synthesizer;
use asrpu::util::cli;
use asrpu::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv, &["n", "batch", "seed"])?;
    let n = args.usize_or("n", 16)?;
    let max_batch = args.usize_or("batch", BatchConfig::default().max_batch)?;
    let seed = args.usize_or("seed", 42)? as u64;

    let engine = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 1))
        .decoder(DecoderConfig::default())
        .batch(BatchConfig { max_batch, ..BatchConfig::default() })
        .build()?;
    let step_len = engine.model_cfg.step_len;

    // N utterances of varying length — sessions will join and drain the
    // ready set at different times, so batches are genuinely dynamic.
    let synth = Synthesizer::default();
    let mut rng = Rng::new(seed);
    let utts: Vec<Vec<f32>> = (0..n)
        .map(|_| synth.render_random(&mut rng).samples)
        .collect();
    let total_audio_s: f64 = utts.iter().map(|u| u.len() as f64 / 16_000.0).sum();
    println!(
        "{n} sessions, {total_audio_s:.1}s of audio, lane-batched at ≤{max_batch} lanes"
    );

    let mut sessions: Vec<Session> =
        (0..n).map(|_| engine.open(false)).collect::<Result<_, _>>()?;

    // Stream one 80 ms chunk per live session per round, then run every
    // ready lane through fused steps in groups of at most `max_batch`.
    let t0 = Instant::now();
    let mut offset = 0;
    let max_len = utts.iter().map(Vec::len).max().unwrap_or(0);
    while offset < max_len {
        for (s, u) in sessions.iter_mut().zip(&utts) {
            if offset < u.len() {
                engine.push_audio(s, &u[offset..(offset + step_len).min(u.len())]);
            }
        }
        offset += step_len;
        for group in sessions.chunks_mut(max_batch) {
            let mut refs: Vec<&mut Session> = group.iter_mut().collect();
            engine.step_batch(&mut refs)?;
        }
    }
    let mut finished = Vec::new();
    for s in sessions.iter_mut() {
        finished.push(engine.finish(s)?);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    for (i, t) in finished.iter().enumerate() {
        let m = &sessions[i].metrics;
        println!(
            "  session {i:>2}: {:>3} steps, occupancy {:.2}, rtf {:>7.1}x  \"{}\"",
            m.steps,
            m.avg_batch_occupancy(),
            m.rtf(),
            t.text
        );
    }
    let batched_steps: usize = sessions.iter().map(|s| s.metrics.batched_steps).sum();
    let batch_lanes: usize = sessions.iter().map(|s| s.metrics.batch_lanes).sum();
    let occupancy = if batched_steps == 0 {
        0.0
    } else {
        batch_lanes as f64 / batched_steps as f64
    };
    println!(
        "aggregate: {total_audio_s:.1}s audio in {:.0}ms wall → {:.1}x real time, \
         mean batch occupancy {occupancy:.2}",
        wall_s * 1e3,
        total_audio_s / wall_s
    );
    Ok(())
}
