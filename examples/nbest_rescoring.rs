//! Exact N-best + second-pass LM rescoring: decode noisy utterances
//! with lattice recording on, pull each utterance's exact N-best list
//! from the lattice, and re-rank it with a higher-order (trigram) LM —
//! the classic two-pass shape, with the guarantee that the lattice's
//! best path is bit-identical to the single-pass transcript. Reports
//! first-pass, second-pass and oracle WER (the oracle picks the best
//! entry per list — the headroom rescoring can claim), plus measured
//! lattice sizes.
//!
//!     make artifacts && cargo run --release --example nbest_rescoring

use asrpu::config::{artifacts_dir, DecoderConfig};
use asrpu::coordinator::Engine;
use asrpu::decoder::TrigramLm;
use asrpu::runtime::Runtime;
use asrpu::synth::{edit_distance, spec, Synthesizer, WerAccum};
use asrpu::util::rng::Rng;
use asrpu::util::table::Table;

const N_UTTERANCES: usize = 24;
const NBEST: usize = 8;
/// Elevated noise so the first pass actually makes recoverable errors.
const NOISE: f64 = 0.9;
/// Second-pass LM weight (replaces the first pass's bigram share).
const RESCORE_WEIGHT: f32 = 1.1;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        artifacts_dir().join("meta.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = Runtime::cpu()?;
    // Second-pass LM: a trigram estimated on a larger corpus sample
    // than the decoding bigram — strictly more context per word.
    let tri = TrigramLm::estimate(&spec::sample_corpus(2000, 7777), 0.4)?;
    let engine = Engine::builder()
        .artifacts(&rt, artifacts_dir())
        .decoder(DecoderConfig::default())
        .nbest(NBEST)
        .rescore(tri, RESCORE_WEIGHT)
        .build()?;

    let synth = Synthesizer { noise_std: NOISE, ..Default::default() };
    let mut rng = Rng::new(4242);
    let mut first = WerAccum::default();
    let mut second = WerAccum::default();
    let mut oracle = WerAccum::default();
    let mut t = Table::new(
        &format!("Two-pass decoding — exact {NBEST}-best + trigram rescoring (noise {NOISE})"),
        &["#", "reference", "1st-pass pick", "2nd-pass pick", "changed", "arcs", "nodes"],
    );
    for i in 0..N_UTTERANCES {
        let words = spec::sample_sentence(&mut rng);
        let u = synth.render(&words, &mut rng);
        let mut s = engine.open(false)?;
        engine.feed(&mut s, &u.samples)?;
        let r = engine.nbest(&mut s)?;
        let re = r.rescored.as_ref().expect("rescorer configured");

        first.add(&u.words, &r.transcript.words);
        second.add(&u.words, &re[0].words);
        let best = r
            .entries
            .iter()
            .min_by_key(|e| edit_distance(&u.words, &e.words))
            .expect("N-best never empty");
        oracle.add(&u.words, &best.words);
        let (arcs, nodes) = s
            .decode
            .lattice()
            .map(|l| (l.num_arcs(), l.num_nodes()))
            .unwrap_or((0, 0));
        t.row(&[
            i.to_string(),
            u.text.clone(),
            r.transcript.text.clone(),
            re[0].text.clone(),
            if re[0].words == r.transcript.words { "".into() } else { "*".into() },
            arcs.to_string(),
            nodes.to_string(),
        ]);
    }
    t.footnote = Some(format!(
        "WER: first pass {:.2}%, second pass {:.2}%, {NBEST}-best oracle {:.2}% \
         (the oracle is the rescoring headroom)",
        first.wer() * 100.0,
        second.wer() * 100.0,
        oracle.wer() * 100.0,
    ));
    println!("{}", t.render());
    Ok(())
}
