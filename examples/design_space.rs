//! Design-space exploration (ablation ABL1): the paper chose 8 PEs at
//! 500 MHz with an 8-wide MAC "to match the performance requirements"
//! (§5.2). This example sweeps those axes with the simulator + the
//! area/power models and prints where real-time decoding becomes
//! feasible and what it costs in silicon and energy — the analysis a
//! hardware team would run before taping out a variant.
//!
//!     cargo run --release --example design_space

use asrpu::accel::{simulate_step, HypWorkload, SimMode};
use asrpu::config::{AccelConfig, ModelConfig};
use asrpu::power::{step_energy_j, ChipBudget};
use asrpu::util::table::Table;

fn row(model: &ModelConfig, accel: &AccelConfig) -> Vec<String> {
    let r = simulate_step(model, accel, &HypWorkload::default(), SimMode::Ideal);
    let b = ChipBudget::for_config(accel);
    let e = step_energy_j(&r, accel);
    let rtf = r.rtf(model, accel);
    vec![
        accel.num_pes.to_string(),
        (accel.frequency_hz / 1_000_000).to_string(),
        accel.mac_vector_width.to_string(),
        format!("{:.1}", r.seconds(accel) * 1e3),
        format!("{:.2}", rtf),
        if rtf >= 1.0 { "yes".into() } else { "NO".into() },
        format!("{:.2}", b.total_area_mm2()),
        format!("{:.2}", b.total_peak_w()),
        format!("{:.1}", e * 1e3),
        format!("{:.1}", e / r.seconds(accel) * 1e3),
    ]
}

fn main() {
    let model = ModelConfig::paper_tds();
    let headers = [
        "PEs", "MHz", "MAC", "Step (ms)", "RTF", "RT?", "Area (mm2)", "Peak (W)",
        "mJ/step", "mW avg",
    ];

    // Axis 1: PE count (the paper's main lever).
    let mut t1 = Table::new("ABL1a — PE-count sweep (500 MHz, 8-wide MAC)", &headers.iter().map(|s| *s).collect::<Vec<_>>());
    for pes in [1, 2, 4, 8, 12, 16, 24, 32] {
        let accel = AccelConfig { num_pes: pes, ..AccelConfig::paper() };
        t1.row(&row(&model, &accel));
    }
    t1.footnote = Some(
        "the paper's 8-PE point is the smallest power-of-two config with ≥2x real time"
            .into(),
    );
    println!("{}", t1.render());

    // Axis 2: frequency at 8 PEs.
    let mut t2 = Table::new("ABL1b — frequency sweep (8 PEs)", &headers.iter().map(|s| *s).collect::<Vec<_>>());
    for mhz in [125, 250, 375, 500, 750, 1000] {
        let accel = AccelConfig {
            frequency_hz: mhz * 1_000_000,
            ..AccelConfig::paper()
        };
        t2.row(&row(&model, &accel));
    }
    println!("{}", t2.render());

    // Axis 3: MAC vector width (the int8 dot-product engine).
    let mut t3 = Table::new("ABL1c — MAC width sweep (8 PEs, 500 MHz)", &headers.iter().map(|s| *s).collect::<Vec<_>>());
    for mac in [1, 2, 4, 8, 16, 32] {
        let accel = AccelConfig {
            mac_vector_width: mac,
            ..AccelConfig::paper()
        };
        t3.row(&row(&model, &accel));
    }
    t3.footnote = Some(
        "MAC width saturates once loop overhead dominates the dot-product loop".into(),
    );
    println!("{}", t3.render());

    // Axis 4: DMA bandwidth sensitivity (Fig. 7's pipelining claim).
    let mut t4 = Table::new(
        "ABL1d — external-bandwidth sensitivity (Detailed mode)",
        &["BW (GB/s)", "Step (ms)", "DMA stalls (kcycles)", "Overhead vs ideal"],
    );
    let ideal = simulate_step(&model, &AccelConfig::paper(), &HypWorkload::default(), SimMode::Ideal);
    for gbps in [0.5, 1.0, 2.0, 4.0, 8.0, 16.0] {
        let accel = AccelConfig {
            ext_mem_bw_bytes_per_s: (gbps * 1e9) as u64,
            ..AccelConfig::paper()
        };
        let r = simulate_step(&model, &accel, &HypWorkload::default(), SimMode::Detailed);
        t4.row(&[
            format!("{gbps}"),
            format!("{:.1}", r.seconds(&accel) * 1e3),
            format!("{}", r.dma_stall_cycles / 1000),
            format!(
                "{:+.1}%",
                100.0 * (r.total_cycles as f64 / ideal.total_cycles as f64 - 1.0)
            ),
        ]);
    }
    t4.footnote = Some(
        "Fig. 7 setup-thread prefetching hides DMA above ~2 GB/s on this model".into(),
    );
    println!("{}", t4.render());
}
