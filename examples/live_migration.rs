//! Live mid-utterance migration under load: open N streaming sessions
//! against a `ShardPool`, run every one of them *past* its first
//! decoding steps, then finish a staggered subset so the router's
//! rebalancer must move started sessions between shards
//! (evict → snapshot → adopt → restore). Optionally crash a worker
//! mid-stream (`--kill`) to demonstrate checkpoint recovery. Every
//! surviving transcript is verified bit-identical to a plain 1-worker
//! engine, and the per-shard adopted/migrated/checkpoint counters are
//! printed.
//!
//!     cargo run --release --example live_migration \
//!         [-- --n 12 --workers 3 --rebalance 2 --kill 1]

use asrpu::am::TdsModel;
use asrpu::config::{BatchConfig, ModelConfig, ShardConfig};
use asrpu::coordinator::{Engine, ShardPool};
use asrpu::synth::Synthesizer;
use asrpu::util::cli;
use asrpu::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv, &["n", "workers", "rebalance", "seed", "kill"])?;
    let n = args.usize_or("n", 12)?;
    let workers = args.usize_or("workers", 3)?;
    let rebalance = args.usize_or("rebalance", 2)?;
    let seed = args.usize_or("seed", 42)? as u64;
    // --kill S crashes shard S after the first feeding round; pass a
    // value >= workers (the default) to skip the crash drill.
    let kill = args.usize_or("kill", usize::MAX)?;
    const MODEL_SEED: u64 = 1;

    let synth = Synthesizer::default();
    let mut rng = Rng::new(seed);
    let utts: Vec<Vec<f32>> = (0..n)
        .map(|_| synth.render_random(&mut rng).samples)
        .collect();

    // The 1-worker reference: same weights, scalar decode per utterance.
    let reference = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
        .build()?;
    let expected: Vec<String> = utts
        .iter()
        .map(|u| Ok(reference.decode_utterance(u)?.0.text))
        .collect::<anyhow::Result<_>>()?;

    let pool = ShardPool::start(
        move || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
                .batch(BatchConfig { max_batch: 8, max_wait_frames: 0 })
                .shards(ShardConfig {
                    workers,
                    rebalance_threshold: rebalance,
                    checkpoint_interval: 1,
                })
                .build()?)
        },
        256,
    )?;
    println!(
        "{n} sessions over {} worker shard(s), rebalance threshold {rebalance}",
        pool.workers()
    );

    // Round 1: start every session (first half of its audio) so all of
    // them are mid-utterance — exactly the population the old
    // queued-only rebalancer could never move.
    let ids: Vec<u64> = (0..n).map(|_| pool.open()).collect::<anyhow::Result<_>>()?;
    for (i, &id) in ids.iter().enumerate() {
        let half = utts[i].len() / 2;
        let (steps, _) = pool.feed(id, &utts[i][..half])?;
        anyhow::ensure!(steps > 0, "session {id} did not start decoding");
    }

    if kill < workers {
        let recovered = pool.kill_worker(kill)?;
        println!("crashed shard {kill}: {recovered} session(s) recovered from checkpoints");
    }

    // Round 2: finish every third session early. Each finish drains a
    // shard and trips the imbalance threshold, so the router migrates
    // *started* sessions toward the cold shards.
    // (These sessions only ever saw half their audio, so their
    // transcripts are intentionally not compared against the reference.)
    let mut done = vec![false; n];
    for (i, &id) in ids.iter().enumerate().filter(|(i, _)| i % 3 == 0) {
        pool.finish(id)?;
        done[i] = true;
    }

    // Round 3: stream the rest of the audio — much of it now lands on
    // shards the sessions were migrated to — and verify transcripts.
    let mut mismatches = 0;
    for (i, &id) in ids.iter().enumerate() {
        if done[i] {
            continue;
        }
        let half = utts[i].len() / 2;
        pool.feed(id, &utts[i][half..])?;
        let t = pool.finish(id)?;
        let ok = t.text == expected[i];
        if !ok {
            mismatches += 1;
        }
        println!(
            "  utt {i:>3} (session {id:>3}): {} \"{}\"",
            if ok { "ok" } else { "MISMATCH" },
            t.text
        );
    }
    anyhow::ensure!(
        mismatches == 0,
        "{mismatches} migrated transcript(s) diverged from the 1-worker engine"
    );

    let stats = pool.stats()?;
    println!(
        "recovered sessions: {}",
        stats.get("recovered").and_then(|v| v.as_f64()).unwrap_or(0.0)
    );
    if let Some(shards) = stats.get("shards").and_then(|s| s.as_arr()) {
        for s in shards {
            println!(
                "  shard {:>2}: sessions {:>2}  adopted {:>2}  migrated {:>2}  checkpoints {:>3}",
                s.get("shard").and_then(|v| v.as_f64()).unwrap_or(-1.0),
                s.get("sessions").and_then(|v| v.as_f64()).unwrap_or(0.0),
                s.get("adopted").and_then(|v| v.as_f64()).unwrap_or(0.0),
                s.get("migrated").and_then(|v| v.as_f64()).unwrap_or(0.0),
                s.get("checkpoints").and_then(|v| v.as_f64()).unwrap_or(0.0),
            );
        }
    }
    let adopted: f64 = stats
        .get("shards")
        .and_then(|s| s.as_arr())
        .map(|arr| {
            arr.iter()
                .filter_map(|s| s.get("adopted").and_then(|v| v.as_f64()))
                .sum()
        })
        .unwrap_or(0.0);
    pool.shutdown();
    println!(
        "{} live migration(s)/recoveries moved started sessions between shards; \
         every finished transcript bit-identical to the 1-worker engine ✓",
        adopted
    );
    Ok(())
}
