//! Quickstart: synthesize one utterance, decode it end-to-end, print the
//! transcript — the smallest complete use of the public API.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Falls back to the native backend with random weights (gibberish
//! transcripts, but the full pipeline) if artifacts are missing.

use asrpu::config::{artifacts_dir, DecoderConfig, ModelConfig};
use asrpu::coordinator::Engine;
use asrpu::runtime::Runtime;
use asrpu::synth::Synthesizer;
use asrpu::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. An engine: MFCC front-end + TDS acoustic model + CTC beam search
    //    with lexicon and n-gram LM, assembled through the builder (the
    //    single construction path).
    let engine = if artifacts_dir().join("meta.json").exists() {
        let rt = Runtime::cpu()?;
        Engine::builder()
            .artifacts(&rt, artifacts_dir())
            .decoder(DecoderConfig::default())
            .build()?
    } else {
        eprintln!("(artifacts missing — native backend with random weights)");
        Engine::builder()
            .native(asrpu::am::TdsModel::random(ModelConfig::tiny_tds(), 1))
            .decoder(DecoderConfig::default())
            .build()?
    };

    // 2. A test utterance from the synthetic-speech protocol.
    let mut rng = Rng::new(7);
    let utterance = Synthesizer::default().render_random(&mut rng);
    println!("reference:  {}", utterance.text);

    // 3. Decode (streaming internally: 80 ms decoding steps).
    let (transcript, metrics) = engine.decode_utterance(&utterance.samples)?;
    println!("hypothesis: {}", transcript.text);
    println!(
        "score {:.2} | {} steps | {:.2}s audio in {:.0}ms compute ({:.0}x real time)",
        transcript.score,
        metrics.steps,
        metrics.audio_s,
        metrics.compute_s * 1e3,
        metrics.rtf()
    );
    Ok(())
}
