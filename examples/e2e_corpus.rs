//! End-to-end validation driver (DESIGN.md experiment E2E): decode a
//! 64-utterance synthetic test corpus through the full stack — Rust
//! synthesis → XLA MFCC (AOT) → trained TDS model with Pallas kernels
//! (AOT, via PJRT) → CTC beam search with lexicon + bigram LM — and
//! report WER, sentence accuracy, latency and real-time factor. In
//! parallel, replay the same search workload through the ASRPU simulator
//! to report what the accelerator would have done (cycles, energy).
//!
//!     make artifacts && cargo run --release --example e2e_corpus
//!
//! Results are recorded in EXPERIMENTS.md.

use asrpu::accel::{simulate_step, HypWorkload, SimMode};
use asrpu::config::{artifacts_dir, AccelConfig, DecoderConfig, ModelConfig};
use asrpu::coordinator::{Engine, LatencyStats};
use asrpu::power::{step_energy_j, ChipBudget};
use asrpu::runtime::Runtime;
use asrpu::synth::{spec, Synthesizer, WerAccum};
use asrpu::util::rng::Rng;
use asrpu::util::table::Table;

const N_UTTERANCES: usize = 64;
const SEED: u64 = 20260710;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        artifacts_dir().join("meta.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = Runtime::cpu()?;
    let engine = Engine::builder()
        .artifacts(&rt, artifacts_dir())
        .decoder(DecoderConfig::default())
        .build()?;
    let synth = Synthesizer::default();
    let mut rng = Rng::new(SEED);

    let mut wer = WerAccum::default();
    let mut greedy_wer = WerAccum::default();
    let mut step_latency = LatencyStats::default();
    let (mut audio_s, mut compute_s, mut am_s, mut search_s) = (0.0, 0.0, 0.0, 0.0);
    let mut stats_total = asrpu::decoder::PruneStats::default();
    let mut mistakes: Vec<(String, String)> = Vec::new();

    for i in 0..N_UTTERANCES {
        let words = spec::sample_sentence(&mut rng);
        let u = synth.render(&words, &mut rng);
        let mut s = engine.open(true)?;
        // Stream in realistic 80 ms microphone chunks.
        for chunk in u.samples.chunks(1280) {
            let t0 = std::time::Instant::now();
            let ran = engine.feed(&mut s, chunk)?;
            if ran > 0 {
                step_latency.record(t0.elapsed());
            }
        }
        let transcript = engine.finish(&mut s)?;
        let greedy = engine.greedy_of(&s)?;
        wer.add(&u.words, &transcript.words);
        greedy_wer.add(&u.words, &greedy.words);
        if transcript.words != u.words && mistakes.len() < 5 {
            mistakes.push((u.text.clone(), transcript.text.clone()));
        }
        audio_s += s.metrics.audio_s;
        compute_s += s.metrics.compute_s;
        am_s += s.metrics.am_s;
        search_s += s.metrics.search_s;
        stats_total.generated += s.decode.stats.generated;
        stats_total.merged += s.decode.stats.merged;
        stats_total.beam_pruned += s.decode.stats.beam_pruned;
        stats_total.capacity_pruned += s.decode.stats.capacity_pruned;
        stats_total.peak_live = stats_total.peak_live.max(s.decode.stats.peak_live);
        stats_total.rounds += s.decode.stats.rounds;
        if (i + 1) % 16 == 0 {
            eprintln!("  {}/{N_UTTERANCES} decoded...", i + 1);
        }
    }

    let mut t = Table::new("E2E — 64-utterance synthetic corpus", &["Metric", "Value"]);
    t.row(&["Utterances".into(), N_UTTERANCES.to_string()]);
    t.row(&["Beam WER".into(), format!("{:.2}%", wer.wer() * 100.0)]);
    t.row(&["Greedy (no lexicon/LM) WER".into(), format!("{:.2}%", greedy_wer.wer() * 100.0)]);
    t.row(&["Sentence accuracy".into(), format!("{:.1}%", wer.sentence_acc() * 100.0)]);
    t.row(&["Audio decoded".into(), format!("{audio_s:.1} s")]);
    t.row(&["Compute".into(), format!("{compute_s:.2} s")]);
    t.row(&["Real-time factor".into(), format!("{:.1}x", audio_s / compute_s)]);
    t.row(&["AM share of compute".into(), format!("{:.0}%", 100.0 * am_s / compute_s)]);
    t.row(&["Search share of compute".into(), format!("{:.0}%", 100.0 * search_s / compute_s)]);
    t.row(&["Step latency p50".into(), format!("{:.2} ms", step_latency.percentile(50.0))]);
    t.row(&["Step latency p99".into(), format!("{:.2} ms", step_latency.percentile(99.0))]);
    t.row(&["Mean live hypotheses".into(), format!("{:.1}", stats_total.mean_live())]);
    t.row(&["Peak live hypotheses".into(), stats_total.peak_live.to_string()]);
    println!("{}", t.render());
    if !mistakes.is_empty() {
        println!("sample errors:");
        for (r, h) in &mistakes {
            println!("  ref: {r}\n  hyp: {h}");
        }
    }

    // What the ASRPU chip itself would have done with the measured search
    // workload (paper-scale model, Table 2 config).
    let accel = AccelConfig::paper();
    let model = ModelConfig::paper_tds();
    let hyp = HypWorkload::from_stats(&stats_total, 8.0, 0.12);
    let r = simulate_step(&model, &accel, &hyp, SimMode::Ideal);
    let b = ChipBudget::for_config(&accel);
    let e = step_energy_j(&r, &accel);
    let mut sim = Table::new(
        "Same search workload on simulated ASRPU (paper-scale AM)",
        &["Metric", "Value"],
    );
    sim.row(&["Live hypotheses fed to simulator".into(), hyp.n_hyps.to_string()]);
    sim.row(&["Decoding step".into(), format!("{:.1} ms", r.seconds(&accel) * 1e3)]);
    sim.row(&["Real-time factor".into(), format!("{:.2}x", r.rtf(&model, &accel))]);
    sim.row(&["Energy / step".into(), format!("{:.1} mJ", e * 1e3)]);
    sim.row(&[
        "Avg power while decoding".into(),
        format!("{:.2} W (peak budget {:.2} W)", e / r.seconds(&accel), b.total_peak_w()),
    ]);
    println!("{}", sim.render());
    Ok(())
}
