//! Beam-width ablation (ABL2): sweep the hypothesis unit's beam and
//! capacity and measure WER, search effort and hypothesis-unit occupancy
//! — the §3.5 / §2.3.1 trade-off between pruning aggressiveness and
//! transcription quality, plus what each point implies for the simulated
//! accelerator's hypothesis-expansion time.
//!
//!     make artifacts && cargo run --release --example beam_sweep

use asrpu::accel::{simulate_step, HypWorkload, SimMode};
use asrpu::config::{artifacts_dir, AccelConfig, DecoderConfig, ModelConfig};
use asrpu::coordinator::Engine;
use asrpu::runtime::Runtime;
use asrpu::synth::{spec, Synthesizer, WerAccum};
use asrpu::util::rng::Rng;
use asrpu::util::table::Table;

const N_UTTERANCES: usize = 24;
/// Beam points are evaluated at elevated noise (the model is trained
/// with 0.0–0.2 noise augmentation; the protocol default is 0.01) so
/// pruning aggressiveness actually costs accuracy.
const SWEEP_NOISE: f64 = 1.0;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        artifacts_dir().join("meta.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = Runtime::cpu()?;
    let accel = AccelConfig::paper();
    let model = ModelConfig::paper_tds();

    // Noise robustness at the default beam (context for the sweep).
    let engine = Engine::builder()
        .artifacts(&rt, artifacts_dir())
        .decoder(DecoderConfig::default())
        .build()?;
    let mut tn = Table::new(
        "ABL2a — noise robustness (default beam 14, greedy vs beam)",
        &["Noise σ", "Beam WER", "Greedy WER", "Sent acc"],
    );
    for noise in [0.01, 0.3, 0.6, 0.9, 1.1, 1.3] {
        let synth = Synthesizer { noise_std: noise, ..Default::default() };
        let mut rng = Rng::new(4242);
        let mut wer = WerAccum::default();
        let mut gw = WerAccum::default();
        for _ in 0..N_UTTERANCES {
            let words = spec::sample_sentence(&mut rng);
            let u = synth.render(&words, &mut rng);
            let mut s = engine.open(true)?;
            engine.feed(&mut s, &u.samples)?;
            let tr = engine.finish(&mut s)?;
            let gr = engine.greedy_of(&s)?;
            wer.add(&u.words, &tr.words);
            gw.add(&u.words, &gr.words);
        }
        tn.row(&[
            format!("{noise}"),
            format!("{:.2}%", wer.wer() * 100.0),
            format!("{:.2}%", gw.wer() * 100.0),
            format!("{:.0}%", wer.sentence_acc() * 100.0),
        ]);
    }
    println!("{}", tn.render());

    let mut t = Table::new(
        "ABL2 — beam width vs WER / search effort / simulated hyp-expansion time",
        &[
            "Beam", "Max hyps", "WER", "Sent acc", "Mean live", "Peak live",
            "Cands/frame", "Sim hyp-exp (ms/step)",
        ],
    );
    for (beam, max_hyps) in [
        (1.0f32, 8usize),
        (3.0, 32),
        (6.0, 96),
        (10.0, 192),
        (14.0, 384),
        (20.0, 384),
    ] {
        let dec = DecoderConfig { beam, max_hyps, ..Default::default() };
        let engine = Engine::builder()
            .artifacts(&rt, artifacts_dir())
            .decoder(dec)
            .build()?;
        let synth = Synthesizer { noise_std: SWEEP_NOISE, ..Default::default() };
        let mut rng = Rng::new(4242); // same corpus for every beam point
        let mut wer = WerAccum::default();
        let mut stats = asrpu::decoder::PruneStats::default();
        for _ in 0..N_UTTERANCES {
            let words = spec::sample_sentence(&mut rng);
            let u = synth.render(&words, &mut rng);
            let mut s = engine.open(false)?;
            engine.feed(&mut s, &u.samples)?;
            let tr = engine.finish(&mut s)?;
            wer.add(&u.words, &tr.words);
            stats.generated += s.decode.stats.generated;
            stats.merged += s.decode.stats.merged;
            stats.beam_pruned += s.decode.stats.beam_pruned;
            stats.capacity_pruned += s.decode.stats.capacity_pruned;
            stats.peak_live = stats.peak_live.max(s.decode.stats.peak_live);
            stats.rounds += s.decode.stats.rounds;
        }
        // Feed the measured workload to the simulator.
        let hyp = HypWorkload::from_stats(&stats, 8.0, 0.12);
        let r = simulate_step(&model, &accel, &hyp, SimMode::Ideal);
        let hyp_ms = r.hyp_cycles as f64 * accel.cycle_s() * 1e3;
        t.row(&[
            format!("{beam}"),
            max_hyps.to_string(),
            format!("{:.2}%", wer.wer() * 100.0),
            format!("{:.0}%", wer.sentence_acc() * 100.0),
            format!("{:.1}", stats.mean_live()),
            stats.peak_live.to_string(),
            format!("{:.1}", stats.generated as f64 / stats.rounds as f64),
            format!("{hyp_ms:.2}"),
        ]);
    }
    t.footnote = Some(format!(
        "{N_UTTERANCES} utterances per point, same corpus; capacity capped at the \
         hypothesis memory's 384 records (Table 2)"
    ));
    println!("{}", t.render());
    Ok(())
}
