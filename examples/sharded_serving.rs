//! Sharded multi-worker serving: open N concurrent synthetic sessions
//! against an in-process `ShardPool` of W device workers sharing one
//! model, stream their audio from client threads, and verify every
//! transcript is bit-identical to a plain 1-worker engine — the
//! cross-shard determinism the serving layer guarantees — before
//! printing per-shard occupancy/queue metrics.
//!
//!     cargo run --release --example sharded_serving [-- --n 16 --workers 4]

use std::time::Instant;

use asrpu::am::TdsModel;
use asrpu::config::{BatchConfig, ModelConfig, ShardConfig};
use asrpu::coordinator::{Engine, ShardPool};
use asrpu::synth::Synthesizer;
use asrpu::util::cli;
use asrpu::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv, &["n", "workers", "rebalance", "seed"])?;
    let n = args.usize_or("n", 16)?;
    let workers = args.usize_or("workers", 4)?;
    let rebalance = args.usize_or("rebalance", ShardConfig::default().rebalance_threshold)?;
    let seed = args.usize_or("seed", 42)? as u64;
    const MODEL_SEED: u64 = 1;

    // N utterances of varying length.
    let synth = Synthesizer::default();
    let mut rng = Rng::new(seed);
    let utts: Vec<Vec<f32>> = (0..n)
        .map(|_| synth.render_random(&mut rng).samples)
        .collect();
    let total_audio_s: f64 = utts.iter().map(|u| u.len() as f64 / 16_000.0).sum();

    // The 1-worker reference: same weights, scalar decode per utterance.
    let reference = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
        .build()?;
    let expected: Vec<String> = utts
        .iter()
        .map(|u| Ok(reference.decode_utterance(u)?.0.text))
        .collect::<anyhow::Result<_>>()?;

    // The sharded pool: W workers over the same (Arc-shared) model.
    let pool = ShardPool::start(
        move || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
                .batch(BatchConfig::default())
                .shards(ShardConfig {
                    workers,
                    rebalance_threshold: rebalance,
                    ..ShardConfig::default()
                })
                .build()?)
        },
        256,
    )?;
    println!(
        "{n} sessions, {total_audio_s:.1}s of audio, {} worker shard(s)",
        pool.workers()
    );

    // One client thread per session: open → feed in ~0.5 s chunks →
    // finish. Feeds from different sessions land on their shards'
    // batchers and fuse into lane-batched device steps.
    let t0 = Instant::now();
    let handles: Vec<_> = utts
        .iter()
        .cloned()
        .enumerate()
        .map(|(i, audio)| {
            let client = pool.clone();
            std::thread::spawn(move || -> anyhow::Result<(usize, u64, String)> {
                let id = client.open()?;
                for chunk in audio.chunks(8000) {
                    client.feed(id, chunk)?;
                }
                let done = client.finish(id)?;
                Ok((i, id, done.text))
            })
        })
        .collect();
    let mut results: Vec<(usize, u64, String)> = handles
        .into_iter()
        .map(|h| h.join().expect("client thread panicked"))
        .collect::<anyhow::Result<_>>()?;
    let wall_s = t0.elapsed().as_secs_f64();
    // Each thread knows which utterance it carried (session ids race
    // across opens and carry no utterance meaning), so the comparison
    // is exact and per-utterance, not a multiset check.
    results.sort_by_key(|(i, _, _)| *i);

    let mut mismatches = 0;
    for (i, id, text) in &results {
        let ok = text == &expected[*i];
        if !ok {
            mismatches += 1;
        }
        println!(
            "  utt {i:>3} (session {id:>3}): {} \"{}\"",
            if ok { "ok" } else { "MISMATCH" },
            text
        );
    }
    anyhow::ensure!(
        mismatches == 0,
        "{mismatches} sharded transcript(s) diverged from the 1-worker engine"
    );

    let stats = pool.stats()?;
    println!(
        "aggregate: {total_audio_s:.1}s audio in {:.0}ms wall → {:.1}x real time",
        wall_s * 1e3,
        total_audio_s / wall_s
    );
    println!(
        "stats: {}",
        stats.get("summary").and_then(|s| s.as_str()).unwrap_or("?")
    );
    if let Some(shards) = stats.get("shards").and_then(|s| s.as_arr()) {
        for s in shards {
            println!(
                "  shard {}: {}",
                s.get("shard").and_then(|v| v.as_f64()).unwrap_or(-1.0),
                s.get("summary").and_then(|v| v.as_str()).unwrap_or("?")
            );
        }
    }
    pool.shutdown();
    println!("every transcript bit-identical to the 1-worker engine ✓");
    Ok(())
}
