//! Streaming-assistant scenario (§2.4): a "microphone" thread feeds 80 ms
//! chunks in real time over the TCP serving protocol while the device
//! thread decodes; partial transcripts print as they stabilize — the
//! low-latency on-edge UX the paper motivates. Ends with server metrics
//! (p50/p99 feed latency, aggregate RTF).
//!
//!     make artifacts && cargo run --release --example streaming_assistant

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use asrpu::config::{artifacts_dir, BatchConfig, DecoderConfig, ModelConfig};
use asrpu::coordinator::{Engine, Server};
use asrpu::runtime::Runtime;
use asrpu::synth::Synthesizer;
use asrpu::util::json::Json;
use asrpu::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let server = Server::start(
        "127.0.0.1:0",
        || {
            let builder = if artifacts_dir().join("meta.json").exists() {
                let rt = Runtime::cpu()?;
                Engine::builder().artifacts(&rt, artifacts_dir())
            } else {
                eprintln!("(artifacts missing — native backend with random weights)");
                Engine::builder().native(asrpu::am::TdsModel::random(ModelConfig::tiny_tds(), 1))
            };
            Ok(builder
                .decoder(DecoderConfig::default())
                .batch(BatchConfig::default())
                .build()?)
        },
        64,
    )?;
    println!("server on {}", server.addr);

    let stream = TcpStream::connect(&server.addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut request = |line: String| -> anyhow::Result<Json> {
        writeln!(writer, "{line}")?;
        let mut resp = String::new();
        reader.read_line(&mut resp)?;
        Ok(Json::parse(resp.trim())?)
    };

    // Three utterances, streamed back-to-back like an assistant session.
    let synth = Synthesizer::default();
    let mut rng = Rng::new(99);
    for utt_no in 0..3 {
        let u = synth.render_random(&mut rng);
        println!("\n--- utterance {utt_no}: \"{}\" ({:.2}s)", u.text, u.samples.len() as f64 / 16000.0);
        let open = request(r#"{"op":"open"}"#.into())?;
        let session = open
            .get("session")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("open failed: {open}"))?;
        let mut last_partial = String::new();
        let t_start = std::time::Instant::now();
        for (i, chunk) in u.samples.chunks(1280).enumerate() {
            // Real-time pacing: one 80 ms chunk every 80 ms.
            let due = std::time::Duration::from_millis(80 * i as u64);
            if let Some(wait) = due.checked_sub(t_start.elapsed()) {
                std::thread::sleep(wait);
            }
            let samples: Vec<String> = chunk.iter().map(|s| format!("{s:.4}")).collect();
            let resp = request(format!(
                r#"{{"op":"feed","session":{session},"samples":[{}]}}"#,
                samples.join(",")
            ))?;
            if let Some(p) = resp.get("partial").and_then(Json::as_str) {
                if p != last_partial && !p.is_empty() {
                    println!("  [{:5.2}s] partial: {p}", t_start.elapsed().as_secs_f64());
                    last_partial = p.to_string();
                }
            }
        }
        let fin = request(format!(r#"{{"op":"finish","session":{session}}}"#))?;
        println!(
            "  final: \"{}\"  (rtf {:.1}x)",
            fin.get("text").and_then(Json::as_str).unwrap_or("?"),
            fin.get("rtf").and_then(Json::as_f64).unwrap_or(0.0)
        );
    }
    let stats = request(r#"{"op":"stats"}"#.into())?;
    println!(
        "\nserver stats: {}",
        stats.get("summary").and_then(Json::as_str).unwrap_or("?")
    );
    server.shutdown();
    Ok(())
}
